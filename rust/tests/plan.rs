//! Acceptance tests for the compile-once `ExecutionPlan` IR (ISSUE 3):
//!
//! 1. plan-backed selection is **byte-identical to the pre-refactor
//!    paths**, proven against an in-test oracle that re-implements the
//!    original argmin (raw `simulate_layer` / `simulate_layer_sharded`
//!    with the historical tie-break) — on the zoo, at 1 chip and 4 chips,
//!    at any thread count;
//! 2. `FlexPipeline::deploy` is plan-backed: deploying a precompiled plan
//!    equals compiling + deploying in one step;
//! 3. plans serialize/deserialize losslessly and carry a provenance key
//!    that is stable across thread counts and cache states.

use flex_tpu::config::ArchConfig;
use flex_tpu::coordinator::plan::{compile_plan, compile_plan_parallel, ExecutionPlan};
use flex_tpu::coordinator::FlexPipeline;
use flex_tpu::sim::engine::{reconfig_charges, simulate_layer, SimOptions};
use flex_tpu::sim::parallel::ShapeCache;
use flex_tpu::sim::shard::simulate_layer_sharded;
use flex_tpu::sim::{Dataflow, ShardStrategy};
use flex_tpu::topology::{zoo, Topology};

fn df_index(df: Dataflow) -> usize {
    Dataflow::ALL.iter().position(|&d| d == df).unwrap()
}

fn strategy_index(s: ShardStrategy) -> usize {
    ShardStrategy::ALL.iter().position(|&x| x == s).unwrap()
}

/// The pre-refactor single-chip selection: per-layer rows from raw
/// `simulate_layer`, argmin with ties toward the `Dataflow::ALL` order.
fn oracle_single_chip(
    arch: &ArchConfig,
    topo: &Topology,
    opts: SimOptions,
) -> Vec<(Dataflow, [u64; 3])> {
    topo.layers
        .iter()
        .map(|layer| {
            let mut row = [0u64; 3];
            for df in Dataflow::ALL {
                row[df_index(df)] = simulate_layer(arch, layer, df, opts).total_cycles();
            }
            let best = Dataflow::ALL
                .into_iter()
                .min_by_key(|&df| row[df_index(df)])
                .unwrap();
            (best, row)
        })
        .collect()
}

#[test]
fn plan_byte_identical_to_oracle_one_chip_any_threads() {
    let arch = ArchConfig::square(32);
    let opts = SimOptions::default();
    for topo in zoo::all_models() {
        let oracle = oracle_single_chip(&arch, &topo, opts);
        for threads in [1usize, 2, 4] {
            let cache = ShapeCache::new();
            let plan = compile_plan_parallel(&arch, &topo, opts, 1, threads, &cache);
            assert_eq!(plan.layers.len(), oracle.len(), "{}", topo.name);
            for (i, (want_df, want_row)) in oracle.iter().enumerate() {
                let l = &plan.layers[i];
                assert_eq!(l.choice.dataflow, *want_df, "{} layer {i}", topo.name);
                for df in Dataflow::ALL {
                    assert_eq!(
                        l.candidates[df_index(df)][0],
                        want_row[df_index(df)],
                        "{} layer {i} {df}",
                        topo.name
                    );
                }
                // Chosen forecast equals the chosen candidate cell.
                assert_eq!(
                    l.layer_cycles(),
                    want_row[df_index(*want_df)],
                    "{} layer {i}",
                    topo.name
                );
            }
            // Plan totals equal the historical roll-up formula.
            let dataflows: Vec<Dataflow> = oracle.iter().map(|(df, _)| *df).collect();
            let flex: u64 = oracle
                .iter()
                .map(|(df, row)| row[df_index(*df)])
                .sum::<u64>()
                + reconfig_charges(&dataflows, arch.reconfig_cycles);
            assert_eq!(plan.flex_cycles(), flex, "{} at {threads} threads", topo.name);
        }
    }
}

#[test]
fn plan_byte_identical_to_oracle_four_chips_any_threads() {
    let arch = ArchConfig::square(32);
    let opts = SimOptions::default();
    let chips = 4u32;
    for topo in [zoo::resnet18(), zoo::mobilenet(), zoo::alexnet()] {
        // Pre-refactor joint selection: raw sharded grids, argmin with ties
        // toward dataflow order first, then strategy order.
        let oracle: Vec<((Dataflow, ShardStrategy), [[u64; 3]; 3])> = topo
            .layers
            .iter()
            .map(|layer| {
                let mut grid = [[0u64; 3]; 3];
                for df in Dataflow::ALL {
                    for st in ShardStrategy::ALL {
                        grid[df_index(df)][strategy_index(st)] =
                            simulate_layer_sharded(&arch, layer, df, st, chips, opts)
                                .total_cycles();
                    }
                }
                let mut best = (Dataflow::Is, ShardStrategy::Rows);
                let mut best_cycles = u64::MAX;
                for df in Dataflow::ALL {
                    for st in ShardStrategy::ALL {
                        let c = grid[df_index(df)][strategy_index(st)];
                        if c < best_cycles {
                            best_cycles = c;
                            best = (df, st);
                        }
                    }
                }
                (best, grid)
            })
            .collect();
        for threads in [1usize, 4] {
            let cache = ShapeCache::new();
            let plan = compile_plan_parallel(&arch, &topo, opts, chips, threads, &cache);
            for (i, ((want_df, want_st), want_grid)) in oracle.iter().enumerate() {
                let l = &plan.layers[i];
                assert_eq!(l.choice.dataflow, *want_df, "{} layer {i}", topo.name);
                assert_eq!(l.choice.strategy, *want_st, "{} layer {i}", topo.name);
                assert_eq!(&l.candidates, want_grid, "{} layer {i}", topo.name);
            }
            // Totals match the historical sharded roll-up.
            let dataflows: Vec<Dataflow> = oracle.iter().map(|((df, _), _)| *df).collect();
            let flex: u64 = oracle
                .iter()
                .map(|((df, st), grid)| grid[df_index(*df)][strategy_index(*st)])
                .sum::<u64>()
                + reconfig_charges(&dataflows, arch.reconfig_cycles);
            assert_eq!(plan.flex_cycles(), flex, "{} at {threads} threads", topo.name);
        }
    }
}

#[test]
fn deploy_is_plan_backed() {
    let arch = ArchConfig::square(16);
    for topo in zoo::all_models() {
        let pipeline = FlexPipeline::new(arch);
        let plan = pipeline.compile(&topo);
        let via_plan = pipeline.deploy_plan(&topo, &plan).unwrap();
        let direct = pipeline.deploy(&topo);
        assert_eq!(via_plan, direct, "{}", topo.name);
        assert_eq!(direct.plan, plan, "{}", topo.name);
        // The deployment's selection is exactly the plan's view.
        assert_eq!(direct.selection, plan.selection(), "{}", topo.name);
        // Plan totals equal the executed network roll-up.
        assert_eq!(direct.total_cycles(), plan.flex_cycles(), "{}", topo.name);
    }
}

#[test]
fn deploy_plan_rejects_mismatched_topology() {
    let arch = ArchConfig::square(16);
    let pipeline = FlexPipeline::new(arch);
    let plan = pipeline.compile(&zoo::alexnet());
    assert!(pipeline.deploy_plan(&zoo::resnet18(), &plan).is_err());
}

#[test]
fn deploy_plan_rejects_multi_chip_plans() {
    // A multi-chip plan's candidate grids hold sharded cycle counts; the
    // single-chip deployment pipeline must refuse to execute it.
    let arch = ArchConfig::square(16);
    let topo = zoo::alexnet();
    let cache = ShapeCache::new();
    let sharded = compile_plan(&arch, &topo, SimOptions::default(), 4, &cache);
    assert!(FlexPipeline::new(arch).deploy_plan(&topo, &sharded).is_err());
}

#[test]
fn plan_json_round_trip_is_lossless() {
    let arch = ArchConfig::square(16);
    let opts = SimOptions::default();
    let cache = ShapeCache::new();
    for chips in [1u32, 4] {
        let plan = compile_plan(&arch, &zoo::googlenet(), opts, chips, &cache);
        let back = ExecutionPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(plan, back, "{chips} chips");
    }
}

#[test]
fn provenance_stable_across_threads_and_caches() {
    let arch = ArchConfig::square(16);
    let opts = SimOptions::default();
    let topo = zoo::vgg13();
    let cold = ShapeCache::new();
    let a = compile_plan(&arch, &topo, opts, 1, &cold);
    let warm = ShapeCache::new();
    // Pre-warm with an unrelated model: must not leak into the plan.
    compile_plan(&arch, &zoo::alexnet(), opts, 1, &warm);
    let b = compile_plan_parallel(&arch, &topo, opts, 1, 4, &warm);
    assert_eq!(a, b, "plan (incl. provenance) must not depend on threads or cache state");
}
