//! Acceptance tests for the deterministic serving bench (ISSUE 5).
//!
//! 1. **byte identity** — same config + same seed produces byte-identical
//!    suite JSON (the property that makes CI perf gating meaningful);
//! 2. **coalescing dominance** — on the gated mixed-model scenario the
//!    `reconfig-aware` policy sustains ≥1.2x `fifo` throughput with no
//!    more reconfigurations, and the property generalizes across seeds
//!    and scenarios;
//! 3. **deadline accounting** — `deadline-edf` books always close
//!    (served + dropped == offered) and overload genuinely drops;
//! 4. **baseline gate** — the committed
//!    `tests/golden/bench_baseline.json` matches a fresh run through the
//!    same `bench::gate` the CI `perf` job runs (bless intentional model
//!    changes with `FLEX_TPU_UPDATE_GOLDEN=1 cargo test --test bench`).

use std::path::PathBuf;
use std::sync::Arc;

use flex_tpu::bench::{self, BenchConfig, BenchSuite, LoopMode, Scenario};
use flex_tpu::config::ArchConfig;
use flex_tpu::inference::{ModelRegistry, SchedulePolicy, SimBackend};
use flex_tpu::util::json::parse;

/// The gated configuration: what CI's `perf` job runs via
/// `flex-tpu bench serve` and what the committed baseline stores.  The
/// 128x128 array is one of the paper's configurations and is the regime
/// where model-switch weight streaming genuinely rivals batch compute
/// (Clockwork's premise), so scheduling order shows up in throughput.
const GATED_MODELS: [&str; 3] = ["alexnet", "resnet18", "vgg13"];
const GATED_SIZE: u32 = 128;
const GATED_BATCH: u32 = 4;

fn registry(size: u32, batch: u32, models: &[&str]) -> Arc<ModelRegistry> {
    let registry = ModelRegistry::new(ArchConfig::square(size), None).unwrap();
    for name in models {
        registry
            .register(Arc::new(SimBackend::from_zoo(name, batch).unwrap()))
            .unwrap();
    }
    Arc::new(registry)
}

fn gated_config() -> BenchConfig {
    BenchConfig {
        scenario: Scenario::MixedModel,
        seed: 7,
        requests: 600,
        mean_interarrival_us: 2_000,
        models: GATED_MODELS.iter().map(|s| s.to_string()).collect(),
        policy: SchedulePolicy::Fifo,
        mode: LoopMode::Open,
        concurrency: 32,
        deadline_us: Some(2_000_000),
        admission: std::collections::BTreeMap::new(),
        priorities: std::collections::BTreeMap::new(),
        overload_control: false,
        seq: None,
    }
}

#[test]
fn same_seed_reports_are_byte_identical() {
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    let cfg = gated_config();
    let a = BenchSuite::run(&reg, &cfg, &SchedulePolicy::ALL).unwrap();
    // A second run on a *fresh* registry (cold cache) must serialize to
    // the same bytes: nothing host-dependent may leak into a report.
    let reg2 = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    let b = BenchSuite::run(&reg2, &cfg, &SchedulePolicy::ALL).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    // And a different seed must not.
    let mut reseeded = cfg.clone();
    reseeded.seed = 8;
    let c = BenchSuite::run(&reg, &reseeded, &SchedulePolicy::ALL).unwrap();
    assert_ne!(a.to_json().to_string(), c.to_json().to_string());
}

#[test]
fn reconfig_aware_dominates_fifo_on_the_gated_scenario() {
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    let suite = BenchSuite::run(
        &reg,
        &gated_config(),
        &[SchedulePolicy::Fifo, SchedulePolicy::ReconfigAware],
    )
    .unwrap();
    let fifo = suite.report("fifo").unwrap();
    let ra = suite.report("reconfig-aware").unwrap();
    assert_eq!(fifo.served, 600);
    assert_eq!(ra.served, 600);
    assert!(
        ra.throughput_rps >= bench::MIN_COALESCING_SPEEDUP * fifo.throughput_rps,
        "reconfig-aware {:.1} rps vs fifo {:.1} rps",
        ra.throughput_rps,
        fifo.throughput_rps
    );
    assert!(
        ra.reconfigurations <= fifo.reconfigurations,
        "reconfig-aware {} vs fifo {}",
        ra.reconfigurations,
        fifo.reconfigurations
    );
    assert!(
        ra.model_switches < fifo.model_switches,
        "coalescing must collapse model switches: {} vs {}",
        ra.model_switches,
        fifo.model_switches
    );
    assert!(
        ra.padded_slots <= fifo.padded_slots,
        "coalescing must not pad more: {} vs {}",
        ra.padded_slots,
        fifo.padded_slots
    );
}

#[test]
fn reconfig_aware_never_exceeds_fifo_reconfigurations_across_seeds() {
    // The property version of the dominance claim: over every scenario
    // and a spread of seeds, reconfig-aware performs at most fifo's
    // reconfigurations and at least its throughput.  (Holding partials
    // until they can no longer coalesce makes each model's launch count
    // the minimum possible, so this is structural, not luck.)
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    for scenario in Scenario::ALL {
        for seed in 0..10u64 {
            let cfg = BenchConfig {
                scenario,
                seed,
                requests: 200,
                deadline_us: None,
                ..gated_config()
            };
            let suite = BenchSuite::run(
                &reg,
                &cfg,
                &[SchedulePolicy::Fifo, SchedulePolicy::ReconfigAware],
            )
            .unwrap();
            let fifo = suite.report("fifo").unwrap();
            let ra = suite.report("reconfig-aware").unwrap();
            assert_eq!(fifo.served, 200, "{scenario} seed {seed}");
            assert_eq!(ra.served, 200, "{scenario} seed {seed}");
            assert!(
                ra.reconfigurations <= fifo.reconfigurations,
                "{scenario} seed {seed}: RA {} > fifo {}",
                ra.reconfigurations,
                fifo.reconfigurations
            );
            assert!(
                ra.throughput_rps >= fifo.throughput_rps,
                "{scenario} seed {seed}: RA {:.1} rps < fifo {:.1} rps",
                ra.throughput_rps,
                fifo.throughput_rps
            );
        }
    }
}

#[test]
fn edf_accounting_closes_and_overload_drops() {
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    // Overloaded open loop with a 2 s budget: the backlog outgrows the
    // deadline, so EDF must drop — and the books must close exactly.
    let cfg = gated_config();
    let suite = BenchSuite::run(&reg, &cfg, &[SchedulePolicy::DeadlineEdf]).unwrap();
    let edf = &suite.reports[0];
    assert_eq!(edf.served + edf.dropped_deadline, edf.offered);
    assert_eq!(edf.offered, 600);
    assert!(edf.dropped_deadline > 0, "overload must miss deadlines");
    for (name, m) in &edf.per_model {
        assert_eq!(m.served + m.dropped_deadline, m.offered, "{name}");
    }
    // Without deadlines the same trace serves everything.
    let mut lax = cfg.clone();
    lax.deadline_us = None;
    let all = BenchSuite::run(&reg, &lax, &[SchedulePolicy::DeadlineEdf]).unwrap();
    assert_eq!(all.reports[0].served, 600);
    assert_eq!(all.reports[0].dropped_deadline, 0);
}

#[test]
fn closed_loop_serves_everything_and_still_prefers_coalescing() {
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    let cfg = BenchConfig {
        mode: LoopMode::Closed,
        concurrency: 24,
        requests: 300,
        deadline_us: None,
        ..gated_config()
    };
    let suite = BenchSuite::run(
        &reg,
        &cfg,
        &[SchedulePolicy::Fifo, SchedulePolicy::ReconfigAware],
    )
    .unwrap();
    let fifo = suite.report("fifo").unwrap();
    let ra = suite.report("reconfig-aware").unwrap();
    assert_eq!(fifo.served, 300);
    assert_eq!(ra.served, 300);
    assert!(
        ra.model_switches < fifo.model_switches,
        "closed loop: {} vs {}",
        ra.model_switches,
        fifo.model_switches
    );
    assert!(ra.throughput_rps > fifo.throughput_rps);
    // Two closed-loop runs are as deterministic as open-loop ones.
    let again = BenchSuite::run(&reg, &cfg, &[SchedulePolicy::Fifo]).unwrap();
    assert_eq!(
        again.reports[0].to_json().to_string(),
        fifo.to_json().to_string()
    );
}

#[test]
fn gated_suite_matches_committed_baseline() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/bench_baseline.json");
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    let suite = BenchSuite::run(&reg, &gated_config(), &SchedulePolicy::ALL).unwrap();
    if std::env::var_os("FLEX_TPU_UPDATE_GOLDEN").is_some() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, format!("{}\n", suite.to_json())).unwrap();
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("baseline {} unreadable: {e}", path.display()));
    let baseline = BenchSuite::from_json(&parse(&text).unwrap()).unwrap();
    match bench::gate(&suite, &baseline) {
        Ok(passed) => assert!(!passed.is_empty()),
        Err(e) => panic!(
            "bench gate failed against the committed baseline: {e}\n\
             If the cycle model or scheduler changed intentionally, regenerate with\n\
             FLEX_TPU_UPDATE_GOLDEN=1 cargo test --test bench\n\
             and commit the diff (it documents the performance drift for review)."
        ),
    }
}

// --------------------------------------------------------------------------
// Pod-scale placement (ISSUE 6): N virtual chips behind one scheduler.

use flex_tpu::coordinator::plan::ReconfigForecast;
use flex_tpu::inference::{ModelProfile, PlacementPolicy, Scheduler};
use flex_tpu::sim::Dataflow;

/// The gated pod: four of the paper's 32x32 chips — committed as
/// `configs/pod_4x32x32.toml` and regenerated here from code so the TOML
/// and the test can never drift apart silently.
fn pod_arch() -> flex_tpu::config::ArchConfig {
    ArchConfig::square(32).with_chips(4)
}

fn pod_registry(placement: PlacementPolicy) -> Arc<ModelRegistry> {
    let registry = ModelRegistry::with_placement(pod_arch(), None, placement).unwrap();
    for name in GATED_MODELS {
        registry
            .register(Arc::new(SimBackend::from_zoo(name, GATED_BATCH).unwrap()))
            .unwrap();
    }
    Arc::new(registry)
}

/// The gated pod policy set: fifo is blind all-chip sharding (the baseline
/// placement must beat), deadline-edf exercises drops at pod width, and
/// placement is the tentpole.  Reconfig-aware is deliberately absent — its
/// 1.2x coalescing gate constant is calibrated to the single-chip 128x128
/// suite, and on the pod placement subsumes its ordering anyway.
const POD_POLICIES: [SchedulePolicy; 3] = [
    SchedulePolicy::Fifo,
    SchedulePolicy::DeadlineEdf,
    SchedulePolicy::Placement,
];

#[test]
fn pod_toml_matches_the_gated_architecture() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/pod_4x32x32.toml");
    let from_file = ArchConfig::from_toml_file(&path).unwrap();
    assert_eq!(from_file, pod_arch(), "configs/pod_4x32x32.toml drifted");
}

#[test]
fn placement_on_a_single_chip_is_the_reconfig_aware_driver_byte_for_byte() {
    // Degenerate pod: one chip, one group.  The placement policy must be
    // indistinguishable from the PR-5 reconfig-aware single-device driver
    // in every number and in the schedule digest — only the policy label
    // may differ.
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    let suite = BenchSuite::run(
        &reg,
        &gated_config(),
        &[SchedulePolicy::ReconfigAware, SchedulePolicy::Placement],
    )
    .unwrap();
    let ra = suite.report("reconfig-aware").unwrap();
    let pl = suite.report("placement").unwrap();
    let mut relabeled = pl.clone();
    relabeled.policy = ra.policy.clone();
    assert_eq!(
        relabeled.to_json().to_string(),
        ra.to_json().to_string(),
        "single-chip placement must degenerate to reconfig-aware"
    );
    assert_eq!(pl.chip_groups, 1);
    assert_eq!(pl.group_cycles, [pl.sim_cycles_total]);
}

#[test]
fn whole_pod_placement_matches_blind_sharding_with_reconfig_aware_order() {
    // The gated model set clusters onto the whole pod under co-locate
    // (shard speedup dominates isolation for these three), so a placement
    // run must equal a reconfig-aware run over the same blind all-chip
    // sharding: one group, same digest, same cycle totals.
    let reg = pod_registry(PlacementPolicy::CoLocate);
    for name in GATED_MODELS {
        assert_eq!(
            reg.placement_of(name).unwrap().chips,
            4,
            "{name} must land on the whole pod"
        );
    }
    let suite = BenchSuite::run(
        &reg,
        &gated_config(),
        &[SchedulePolicy::ReconfigAware, SchedulePolicy::Placement],
    )
    .unwrap();
    let ra = suite.report("reconfig-aware").unwrap();
    let pl = suite.report("placement").unwrap();
    assert_eq!(pl.schedule_digest, ra.schedule_digest);
    assert_eq!(pl.sim_cycles_total, ra.sim_cycles_total);
    assert_eq!(pl.reconfigurations, ra.reconfigurations);
    assert_eq!(pl.chip_groups, 1);
}

#[test]
fn pod_reports_are_deterministic_and_group_cycles_sum_to_total() {
    let cfg = BenchConfig::builder(GATED_MODELS.iter().map(|s| s.to_string()).collect())
        .deadline_us(Some(2_000_000))
        .build();
    let a = BenchSuite::run(&pod_registry(PlacementPolicy::CoLocate), &cfg, &POD_POLICIES)
        .unwrap();
    // A fresh registry (cold cache, recomputed placement) must serialize
    // to the same bytes.
    let b = BenchSuite::run(&pod_registry(PlacementPolicy::CoLocate), &cfg, &POD_POLICIES)
        .unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    for report in &a.reports {
        assert_eq!(
            report.group_cycles.iter().sum::<u64>(),
            report.sim_cycles_total,
            "{}: per-group cycles must partition the total",
            report.policy
        );
        assert_eq!(report.group_cycles.len() as u64, report.chip_groups);
        assert_eq!(report.served + report.dropped_deadline, report.offered);
    }
}

#[test]
fn co_located_compatible_pair_never_pays_more_reconfigs_than_isolated() {
    // Scheduler-level oracle for the co-location bet: two models whose
    // boundary dataflows agree can share a chip group without ever paying
    // more entry switches than the same pair on isolated groups.
    let forecast = |first, last| ReconfigForecast {
        first: Some(first),
        last: Some(last),
        internal_switches: 2,
    };
    let run = |colocated: bool| -> u64 {
        let mut s: Scheduler<u64> = Scheduler::new(SchedulePolicy::Placement);
        for (i, name) in ["ws_a", "ws_b"].iter().enumerate() {
            s.set_profile(ModelProfile {
                model: name.to_string(),
                batch: 2,
                forecast: forecast(Dataflow::Ws, Dataflow::Ws),
                priority: 0,
            });
            s.assign_group(name, if colocated { 0 } else { i });
        }
        for i in 0..16u64 {
            s.push(if i % 2 == 0 { "ws_a" } else { "ws_b" }, i, None, i);
        }
        let mut total = 0;
        let mut expired = Vec::new();
        for group in [0usize, 1] {
            while let Some(plan) = s.pop_group(group, 100, true, &mut expired) {
                total += plan.reconfigurations;
            }
        }
        assert!(expired.is_empty());
        total
    };
    assert!(
        run(true) <= run(false),
        "compatible co-location must not add reconfigurations"
    );

    // And the contrapositive sanity check: an incompatible pair sharing a
    // group alternates dataflows, paying entry switches isolation avoids.
    let run_mixed = |colocated: bool| -> u64 {
        let mut s: Scheduler<u64> = Scheduler::new(SchedulePolicy::Placement);
        let pair = [("ws_model", Dataflow::Ws), ("os_model", Dataflow::Os)];
        for (i, (name, df)) in pair.iter().enumerate() {
            s.set_profile(ModelProfile {
                model: name.to_string(),
                batch: 2,
                forecast: ReconfigForecast {
                    first: Some(*df),
                    last: Some(*df),
                    internal_switches: 0,
                },
                priority: 0,
            });
            s.assign_group(name, if colocated { 0 } else { i });
        }
        for i in 0..16u64 {
            s.push(if i % 2 == 0 { "ws_model" } else { "os_model" }, i, None, i);
        }
        let mut total = 0;
        let mut expired = Vec::new();
        for group in [0usize, 1] {
            while let Some(plan) = s.pop_group(group, 100, true, &mut expired) {
                total += plan.reconfigurations;
            }
        }
        total
    };
    assert!(
        run_mixed(true) > run_mixed(false),
        "incompatible co-location must cost entry switches isolation avoids"
    );
}

#[test]
fn placement_beats_blind_all_chip_sharding_on_the_gated_pod_scenario() {
    // The tentpole acceptance criterion: on the mixed 3-model pod
    // scenario, placement-aware scheduling beats blind all-chip sharding
    // (fifo over the whole pod) on throughput at no more reconfigurations.
    let reg = pod_registry(PlacementPolicy::CoLocate);
    let suite = BenchSuite::run(&reg, &gated_config(), &POD_POLICIES).unwrap();
    let fifo = suite.report("fifo").unwrap();
    let pl = suite.report("placement").unwrap();
    assert!(
        pl.throughput_rps > fifo.throughput_rps,
        "placement {:.1} rps vs blind sharding {:.1} rps",
        pl.throughput_rps,
        fifo.throughput_rps
    );
    assert!(
        pl.reconfigurations <= fifo.reconfigurations,
        "placement {} vs blind sharding {}",
        pl.reconfigurations,
        fifo.reconfigurations
    );
}

// --------------------------------------------------------------------------
// Mixed CNN + transformer fleet (ISSUE 10): a zoo CNN and a bucketed
// transformer share one registry; the trace draws per-request sequence
// lengths and the driver routes each request to its power-of-two bucket.

use flex_tpu::bench::{SeqDist, TraceSpec};
use flex_tpu::topology::synth::{SeqBuckets, SeqFamily, SeqModel};

fn seq_buckets() -> SeqBuckets {
    SeqBuckets::new(32, 128).unwrap()
}

/// The gated mixed fleet: alexnet (dense) + a seed-3 transformer compiled
/// at three sequence buckets, all on the 128x128 array.
fn seq_registry() -> Arc<ModelRegistry> {
    let registry = ModelRegistry::new(ArchConfig::square(GATED_SIZE), None).unwrap();
    registry
        .register(Arc::new(SimBackend::from_zoo("alexnet", GATED_BATCH).unwrap()))
        .unwrap();
    registry
        .register_seq(
            "transformer3",
            &SeqModel::from_seed(SeqFamily::Transformer, 3),
            GATED_BATCH,
            seq_buckets(),
        )
        .unwrap();
    Arc::new(registry)
}

fn seq_config() -> BenchConfig {
    BenchConfig {
        // Seed 3 (not the dense suite's 7) so the uniform 32..128 draw
        // hits all three buckets, including exactly-32 for the bottom one.
        seed: 3,
        requests: 400,
        deadline_us: None,
        models: vec!["alexnet".to_string(), "transformer3".to_string()],
        seq: Some(seq_buckets()),
        ..gated_config()
    }
}

#[test]
fn seq_suite_is_deterministic_and_routes_every_bucket() {
    let cfg = seq_config();
    let policies = [SchedulePolicy::Fifo, SchedulePolicy::ReconfigAware];
    let a = BenchSuite::run(&seq_registry(), &cfg, &policies).unwrap();
    // A fresh registry (cold cache, recompiled bucket plans) must
    // serialize to the same bytes.
    let b = BenchSuite::run(&seq_registry(), &cfg, &policies).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert_eq!(a.seq_min, 32);
    assert_eq!(a.seq_max, 128);
    for report in &a.reports {
        assert_eq!(report.served, 400, "{}", report.policy);
        // Every bucket is a first-class per-model row; the 32..128 draw
        // range touches all three.
        for name in ["alexnet", "transformer3@32", "transformer3@64", "transformer3@128"] {
            let m = report
                .per_model
                .get(name)
                .unwrap_or_else(|| panic!("{}: missing per-model row {name}", report.policy));
            assert!(m.offered > 0, "{}: {name} never offered", report.policy);
            assert_eq!(m.served, m.offered, "{}: {name} books must close", report.policy);
        }
        let offered: u64 = report.per_model.values().map(|m| m.offered).sum();
        assert_eq!(offered, 400, "{}: per-bucket offers partition the trace", report.policy);
    }
    // A different seed draws different sequence lengths.
    let mut reseeded = cfg.clone();
    reseeded.seed = 8;
    let c = BenchSuite::run(&seq_registry(), &reseeded, &policies).unwrap();
    assert_ne!(a.to_json().to_string(), c.to_json().to_string());
}

#[test]
fn seq_gated_suite_matches_committed_baseline() {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/bench_seq_baseline.json");
    let suite = BenchSuite::run(
        &seq_registry(),
        &seq_config(),
        &[SchedulePolicy::Fifo, SchedulePolicy::ReconfigAware],
    )
    .unwrap();
    if std::env::var_os("FLEX_TPU_UPDATE_GOLDEN").is_some() || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, format!("{}\n", suite.to_json())).unwrap();
        if std::env::var_os("FLEX_TPU_UPDATE_GOLDEN").is_none() {
            eprintln!(
                "NOTE: wrote missing seq bench baseline {} — commit it so CI gates \
                 against a fixed reference",
                path.display()
            );
        }
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("seq baseline {} unreadable: {e}", path.display()));
    let baseline = BenchSuite::from_json(&parse(&text).unwrap()).unwrap();
    match bench::gate(&suite, &baseline) {
        Ok(passed) => assert!(!passed.is_empty()),
        Err(e) => panic!(
            "seq bench gate failed against the committed baseline: {e}\n\
             If the cycle model, scheduler or generators changed intentionally,\n\
             regenerate with\n\
             FLEX_TPU_UPDATE_GOLDEN=1 cargo test --test bench\n\
             and commit the diff (it documents the performance drift for review)."
        ),
    }
}

/// FNV-1a over the trace stream — the digest the committed trace baseline
/// stores (and the offline Python replica recomputes independently).
fn trace_digest(spec: &TraceSpec) -> (u64, u64, u64, std::collections::BTreeMap<String, u64>) {
    let buckets = seq_buckets();
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    let mut last_at = 0u64;
    let mut seq_sum = 0u64;
    let mut offered: std::collections::BTreeMap<String, u64> = Default::default();
    for e in spec.events() {
        eat(&e.at_us.to_le_bytes());
        eat(&e.id.to_le_bytes());
        eat(&(e.model as u64).to_le_bytes());
        eat(&u64::from(e.seq_len.unwrap_or(0)).to_le_bytes());
        eat(b";");
        last_at = e.at_us;
        seq_sum += u64::from(e.seq_len.unwrap_or(0));
        let name = match e.model {
            0 => "alexnet".to_string(),
            _ => format!("transformer3@{}", buckets.bucket(e.seq_len.unwrap_or(1))),
        };
        *offered.entry(name).or_insert(0) += 1;
    }
    (h, last_at, seq_sum, offered)
}

#[test]
fn seq_trace_matches_committed_python_replica_baseline() {
    // The committed trace baseline is generated by the *offline Python
    // replica* (python/tools/gen_seq_trace_baseline.py), which reimplements
    // the LCG, the gap/model/sequence draw order and the bucket rounding
    // from scratch.  Equality here cross-validates the Rust generator
    // against an independent implementation, bit for bit.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/bench_seq_trace_baseline.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("trace baseline {} unreadable: {e}", path.display()));
    let doc = parse(&text).unwrap();
    let cfg = seq_config();
    assert_eq!(doc.req_u64("schema").unwrap(), 1);
    assert_eq!(doc.req_str("scenario").unwrap(), cfg.scenario.name());
    assert_eq!(doc.req_u64("seed").unwrap(), cfg.seed);
    assert_eq!(doc.req_u64("requests").unwrap(), cfg.requests);
    assert_eq!(doc.req_u64("mean_interarrival_us").unwrap(), cfg.mean_interarrival_us);
    assert_eq!(doc.req_u64("seq_min").unwrap(), 32);
    assert_eq!(doc.req_u64("seq_max").unwrap(), 128);
    let spec = TraceSpec {
        scenario: cfg.scenario,
        seed: cfg.seed,
        requests: cfg.requests,
        models: cfg.models.len(),
        mean_interarrival_us: cfg.mean_interarrival_us,
        seq: Some(SeqDist {
            min: 32,
            max: 128,
            seq_models: vec![1],
        }),
    };
    let (digest, last_at, seq_sum, offered) = trace_digest(&spec);
    assert_eq!(
        format!("{digest:016x}"),
        doc.req_str("trace_digest").unwrap(),
        "trace digest diverged from the Python replica"
    );
    assert_eq!(doc.req_u64("last_at_us").unwrap(), last_at);
    assert_eq!(doc.req_u64("seq_len_sum").unwrap(), seq_sum);
    let want = doc.req("offered").unwrap();
    let want = want.as_object_sorted().unwrap();
    assert_eq!(want.len(), offered.len(), "offered route set diverged");
    for (name, count) in &offered {
        let got = want
            .get(name.as_str())
            .and_then(|v| v.as_u64())
            .unwrap_or_else(|| panic!("baseline missing offered count for {name}"));
        assert_eq!(got, *count, "{name}");
    }
}

#[test]
fn gated_pod_suite_matches_committed_baseline() {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/bench_pod_baseline.json");
    let reg = pod_registry(PlacementPolicy::CoLocate);
    let suite = BenchSuite::run(&reg, &gated_config(), &POD_POLICIES).unwrap();
    if std::env::var_os("FLEX_TPU_UPDATE_GOLDEN").is_some() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, format!("{}\n", suite.to_json())).unwrap();
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("pod baseline {} unreadable: {e}", path.display()));
    let baseline = BenchSuite::from_json(&parse(&text).unwrap()).unwrap();
    match bench::gate(&suite, &baseline) {
        Ok(passed) => assert!(!passed.is_empty()),
        Err(e) => panic!(
            "pod bench gate failed against the committed baseline: {e}\n\
             If the cycle model, shard model or placement solver changed\n\
             intentionally, regenerate with\n\
             FLEX_TPU_UPDATE_GOLDEN=1 cargo test --test bench\n\
             and commit the diff (it documents the performance drift for review)."
        ),
    }
}
