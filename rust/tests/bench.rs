//! Acceptance tests for the deterministic serving bench (ISSUE 5).
//!
//! 1. **byte identity** — same config + same seed produces byte-identical
//!    suite JSON (the property that makes CI perf gating meaningful);
//! 2. **coalescing dominance** — on the gated mixed-model scenario the
//!    `reconfig-aware` policy sustains ≥1.2x `fifo` throughput with no
//!    more reconfigurations, and the property generalizes across seeds
//!    and scenarios;
//! 3. **deadline accounting** — `deadline-edf` books always close
//!    (served + dropped == offered) and overload genuinely drops;
//! 4. **baseline gate** — the committed
//!    `tests/golden/bench_baseline.json` matches a fresh run through the
//!    same `bench::gate` the CI `perf` job runs (bless intentional model
//!    changes with `FLEX_TPU_UPDATE_GOLDEN=1 cargo test --test bench`).

use std::path::PathBuf;
use std::sync::Arc;

use flex_tpu::bench::{self, BenchConfig, BenchSuite, LoopMode, Scenario};
use flex_tpu::config::ArchConfig;
use flex_tpu::inference::{ModelRegistry, SchedulePolicy, SimBackend};
use flex_tpu::util::json::parse;

/// The gated configuration: what CI's `perf` job runs via
/// `flex-tpu bench serve` and what the committed baseline stores.  The
/// 128x128 array is one of the paper's configurations and is the regime
/// where model-switch weight streaming genuinely rivals batch compute
/// (Clockwork's premise), so scheduling order shows up in throughput.
const GATED_MODELS: [&str; 3] = ["alexnet", "resnet18", "vgg13"];
const GATED_SIZE: u32 = 128;
const GATED_BATCH: u32 = 4;

fn registry(size: u32, batch: u32, models: &[&str]) -> Arc<ModelRegistry> {
    let registry = ModelRegistry::new(ArchConfig::square(size), None).unwrap();
    for name in models {
        registry
            .register(Arc::new(SimBackend::from_zoo(name, batch).unwrap()))
            .unwrap();
    }
    Arc::new(registry)
}

fn gated_config() -> BenchConfig {
    BenchConfig {
        scenario: Scenario::MixedModel,
        seed: 7,
        requests: 600,
        mean_interarrival_us: 2_000,
        models: GATED_MODELS.iter().map(|s| s.to_string()).collect(),
        policy: SchedulePolicy::Fifo,
        mode: LoopMode::Open,
        concurrency: 32,
        deadline_us: Some(2_000_000),
    }
}

#[test]
fn same_seed_reports_are_byte_identical() {
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    let cfg = gated_config();
    let a = BenchSuite::run(&reg, &cfg, &SchedulePolicy::ALL).unwrap();
    // A second run on a *fresh* registry (cold cache) must serialize to
    // the same bytes: nothing host-dependent may leak into a report.
    let reg2 = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    let b = BenchSuite::run(&reg2, &cfg, &SchedulePolicy::ALL).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    // And a different seed must not.
    let mut reseeded = cfg.clone();
    reseeded.seed = 8;
    let c = BenchSuite::run(&reg, &reseeded, &SchedulePolicy::ALL).unwrap();
    assert_ne!(a.to_json().to_string(), c.to_json().to_string());
}

#[test]
fn reconfig_aware_dominates_fifo_on_the_gated_scenario() {
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    let suite = BenchSuite::run(
        &reg,
        &gated_config(),
        &[SchedulePolicy::Fifo, SchedulePolicy::ReconfigAware],
    )
    .unwrap();
    let fifo = suite.report("fifo").unwrap();
    let ra = suite.report("reconfig-aware").unwrap();
    assert_eq!(fifo.served, 600);
    assert_eq!(ra.served, 600);
    assert!(
        ra.throughput_rps >= bench::MIN_COALESCING_SPEEDUP * fifo.throughput_rps,
        "reconfig-aware {:.1} rps vs fifo {:.1} rps",
        ra.throughput_rps,
        fifo.throughput_rps
    );
    assert!(
        ra.reconfigurations <= fifo.reconfigurations,
        "reconfig-aware {} vs fifo {}",
        ra.reconfigurations,
        fifo.reconfigurations
    );
    assert!(
        ra.model_switches < fifo.model_switches,
        "coalescing must collapse model switches: {} vs {}",
        ra.model_switches,
        fifo.model_switches
    );
    assert!(
        ra.padded_slots <= fifo.padded_slots,
        "coalescing must not pad more: {} vs {}",
        ra.padded_slots,
        fifo.padded_slots
    );
}

#[test]
fn reconfig_aware_never_exceeds_fifo_reconfigurations_across_seeds() {
    // The property version of the dominance claim: over every scenario
    // and a spread of seeds, reconfig-aware performs at most fifo's
    // reconfigurations and at least its throughput.  (Holding partials
    // until they can no longer coalesce makes each model's launch count
    // the minimum possible, so this is structural, not luck.)
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    for scenario in Scenario::ALL {
        for seed in 0..10u64 {
            let cfg = BenchConfig {
                scenario,
                seed,
                requests: 200,
                deadline_us: None,
                ..gated_config()
            };
            let suite = BenchSuite::run(
                &reg,
                &cfg,
                &[SchedulePolicy::Fifo, SchedulePolicy::ReconfigAware],
            )
            .unwrap();
            let fifo = suite.report("fifo").unwrap();
            let ra = suite.report("reconfig-aware").unwrap();
            assert_eq!(fifo.served, 200, "{scenario} seed {seed}");
            assert_eq!(ra.served, 200, "{scenario} seed {seed}");
            assert!(
                ra.reconfigurations <= fifo.reconfigurations,
                "{scenario} seed {seed}: RA {} > fifo {}",
                ra.reconfigurations,
                fifo.reconfigurations
            );
            assert!(
                ra.throughput_rps >= fifo.throughput_rps,
                "{scenario} seed {seed}: RA {:.1} rps < fifo {:.1} rps",
                ra.throughput_rps,
                fifo.throughput_rps
            );
        }
    }
}

#[test]
fn edf_accounting_closes_and_overload_drops() {
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    // Overloaded open loop with a 2 s budget: the backlog outgrows the
    // deadline, so EDF must drop — and the books must close exactly.
    let cfg = gated_config();
    let suite = BenchSuite::run(&reg, &cfg, &[SchedulePolicy::DeadlineEdf]).unwrap();
    let edf = &suite.reports[0];
    assert_eq!(edf.served + edf.dropped_deadline, edf.offered);
    assert_eq!(edf.offered, 600);
    assert!(edf.dropped_deadline > 0, "overload must miss deadlines");
    for (name, m) in &edf.per_model {
        assert_eq!(m.served + m.dropped_deadline, m.offered, "{name}");
    }
    // Without deadlines the same trace serves everything.
    let mut lax = cfg.clone();
    lax.deadline_us = None;
    let all = BenchSuite::run(&reg, &lax, &[SchedulePolicy::DeadlineEdf]).unwrap();
    assert_eq!(all.reports[0].served, 600);
    assert_eq!(all.reports[0].dropped_deadline, 0);
}

#[test]
fn closed_loop_serves_everything_and_still_prefers_coalescing() {
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    let cfg = BenchConfig {
        mode: LoopMode::Closed,
        concurrency: 24,
        requests: 300,
        deadline_us: None,
        ..gated_config()
    };
    let suite = BenchSuite::run(
        &reg,
        &cfg,
        &[SchedulePolicy::Fifo, SchedulePolicy::ReconfigAware],
    )
    .unwrap();
    let fifo = suite.report("fifo").unwrap();
    let ra = suite.report("reconfig-aware").unwrap();
    assert_eq!(fifo.served, 300);
    assert_eq!(ra.served, 300);
    assert!(
        ra.model_switches < fifo.model_switches,
        "closed loop: {} vs {}",
        ra.model_switches,
        fifo.model_switches
    );
    assert!(ra.throughput_rps > fifo.throughput_rps);
    // Two closed-loop runs are as deterministic as open-loop ones.
    let again = BenchSuite::run(&reg, &cfg, &[SchedulePolicy::Fifo]).unwrap();
    assert_eq!(
        again.reports[0].to_json().to_string(),
        fifo.to_json().to_string()
    );
}

#[test]
fn gated_suite_matches_committed_baseline() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/bench_baseline.json");
    let reg = registry(GATED_SIZE, GATED_BATCH, &GATED_MODELS);
    let suite = BenchSuite::run(&reg, &gated_config(), &SchedulePolicy::ALL).unwrap();
    if std::env::var_os("FLEX_TPU_UPDATE_GOLDEN").is_some() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, format!("{}\n", suite.to_json())).unwrap();
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("baseline {} unreadable: {e}", path.display()));
    let baseline = BenchSuite::from_json(&parse(&text).unwrap()).unwrap();
    match bench::gate(&suite, &baseline) {
        Ok(passed) => assert!(!passed.is_empty()),
        Err(e) => panic!(
            "bench gate failed against the committed baseline: {e}\n\
             If the cycle model or scheduler changed intentionally, regenerate with\n\
             FLEX_TPU_UPDATE_GOLDEN=1 cargo test --test bench\n\
             and commit the diff (it documents the performance drift for review)."
        ),
    }
}
