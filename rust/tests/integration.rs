//! Cross-module integration tests: topology -> simulator -> coordinator ->
//! cost model -> reports, plus config/CMU persistence round-trips.

use flex_tpu::config::{ArchConfig, SimFidelity};
use flex_tpu::coordinator::cmu::Cmu;
use flex_tpu::coordinator::{dataflow_gen, FlexPipeline, MainController};
use flex_tpu::sim::engine::{simulate_layer, simulate_network, SimOptions};
use flex_tpu::sim::{layer_gemms, Dataflow, DwMapping, Gemm};
use flex_tpu::topology::{parse_csv_str, zoo};
use flex_tpu::util::rng::{property, Rng};

#[test]
fn end_to_end_deploy_from_csv_text() {
    // A user-authored ScaleSim CSV goes through the whole pipeline.
    let csv = "\
Layer name, IFMAP Height, IFMAP Width, Filter Height, Filter Width, Channels, Num Filter, Strides,
ConvA, 34, 34, 3, 3, 16, 32, 1,
ConvB, 18, 18, 3, 3, 32, 64, 2,
FC, 1, 1, 1, 1, 1024, 100, 1,
";
    let topo = parse_csv_str("custom", csv).unwrap();
    let d = FlexPipeline::new(ArchConfig::square(16)).deploy(&topo);
    assert_eq!(d.selection.per_layer.len(), 3);
    for df in Dataflow::ALL {
        assert!(d.speedup_vs(df) >= 1.0);
    }
}

#[test]
fn cmu_image_roundtrip_through_controller() {
    let topo = zoo::googlenet();
    let arch = ArchConfig::square(32);
    let d = FlexPipeline::new(arch).deploy(&topo);
    let cmu = Cmu::program(&topo.name, d.selection.per_layer.clone()).unwrap();
    let json = cmu.to_json().unwrap();
    let restored = Cmu::from_json(&json).unwrap();
    assert_eq!(restored.table(), cmu.table());
    // The restored image drives the controller to the same cycle count.
    let mc = MainController::new(arch, restored);
    let stats = mc.run_timing(&topo, SimOptions::default()).unwrap();
    assert_eq!(stats.total_cycles(), d.total_cycles());
}

#[test]
fn arch_config_file_roundtrip() {
    let dir = std::env::temp_dir().join("flex_tpu_test_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("edge.toml");
    std::fs::write(
        &path,
        "array_rows = 8\narray_cols = 8\nreconfig_cycles = 2\n[memory]\ndram_bytes_per_cycle = 16\n",
    )
    .unwrap();
    let cfg = ArchConfig::from_toml_file(&path).unwrap();
    assert_eq!(cfg.array_rows, 8);
    assert_eq!(cfg.reconfig_cycles, 2);
    assert_eq!(cfg.memory.dram_bytes_per_cycle, 16);
}

#[test]
fn dataflow_generator_streams_match_plan_traffic() {
    // The address generator and the analytical traffic model must agree on
    // single-fold GEMMs (the generator enumerates, the plan counts).
    let arch = ArchConfig::square(4);
    property("addr-gen-traffic", 0xADD, 25, |rng: &mut Rng| {
        let g = Gemm::new(
            rng.range_u64(1, 4),
            rng.range_u64(1, 4),
            rng.range_u64(1, 4),
        );
        for df in Dataflow::ALL {
            let plan = flex_tpu::sim::dataflow::plan(&g, &arch, df);
            if plan.folds() != 1 {
                continue;
            }
            let s = dataflow_gen::generate(&g, &arch, df, 0, 0);
            assert_eq!(s.ifmap_reads.len() as u64, g.m * g.k, "{df} ifmap");
            assert_eq!(s.filter_reads.len() as u64, g.k * g.n, "{df} filter");
            assert_eq!(s.ofmap_writes.len() as u64, g.m * g.n, "{df} ofmap");
        }
    });
}

#[test]
fn grouped_dw_is_slower_but_honest() {
    // Grouped depthwise lowering wastes the array (N=1 per launch) but
    // reports true MACs; dense matches ScaleSim. Both must simulate.
    let arch = ArchConfig::square(32);
    let dw = zoo::mobilenet()
        .layers
        .iter()
        .find(|l| l.name.contains("dw"))
        .unwrap()
        .clone();
    let literal = simulate_layer(&arch, &dw, Dataflow::Os, SimOptions::default());
    let grouped = simulate_layer(
        &arch,
        &dw,
        Dataflow::Os,
        SimOptions {
            dw_mapping: DwMapping::Grouped,
            ..Default::default()
        },
    );
    assert!(grouped.launches > literal.launches);
    // Same true MAC volume, very different schedule.
    assert_eq!(grouped.macs, literal.macs);
    assert!(grouped.compute_cycles > literal.compute_cycles);
    assert_eq!(
        layer_gemms(&dw, DwMapping::Grouped).len() as u64,
        grouped.launches
    );
}

#[test]
fn memory_fidelity_consistency_across_zoo() {
    // WithMemory >= Analytical on totals; equal on compute cycles.
    let arch = ArchConfig::square(32);
    for topo in zoo::all_models() {
        for df in Dataflow::ALL {
            let a = simulate_network(&arch, &topo, df, SimOptions::default());
            let m = simulate_network(
                &arch,
                &topo,
                df,
                SimOptions {
                    fidelity: SimFidelity::WithMemory,
                    ..Default::default()
                },
            );
            assert_eq!(a.compute_cycles(), m.compute_cycles(), "{} {df}", topo.name);
            assert!(m.total_cycles() >= a.total_cycles(), "{} {df}", topo.name);
        }
    }
}

#[test]
fn reconfig_overhead_is_negligible_at_default_cost() {
    // Paper claim: per-layer reconfiguration is effectively free. At the
    // default 1-cycle broadcast, reconfig must be < 0.01% of total.
    let arch = ArchConfig::square(32);
    for topo in zoo::all_models() {
        let d = FlexPipeline::new(arch).deploy(&topo);
        let frac = d.flex.reconfig_cycles as f64 / d.total_cycles() as f64;
        assert!(frac < 1e-4, "{}: reconfig fraction {frac}", topo.name);
    }
}

#[test]
fn network_cycles_are_sum_of_layers_plus_reconfig() {
    let arch = ArchConfig::square(16);
    let topo = zoo::alexnet();
    let d = FlexPipeline::new(arch).deploy(&topo);
    let layer_sum: u64 = d.flex.layers.iter().map(|l| l.total_cycles()).sum();
    assert_eq!(d.total_cycles(), layer_sum + d.flex.reconfig_cycles);
}

#[test]
fn selector_matches_bruteforce_network_minimum() {
    // The per-layer argmin must equal brute-force searching all 3^L static
    // assignments restricted per layer (which is exactly per-layer argmin
    // since layers are independent) — sanity that no cross-layer coupling
    // is being ignored besides reconfig, which is negligible.
    let arch = ArchConfig::square(8);
    let topo = zoo::yolo_tiny();
    let d = FlexPipeline::new(arch).deploy(&topo);
    let mut best_sum = 0u64;
    for layer in &topo.layers {
        best_sum += Dataflow::ALL
            .into_iter()
            .map(|df| simulate_layer(&arch, layer, df, SimOptions::default()).total_cycles())
            .min()
            .unwrap();
    }
    assert_eq!(d.flex.total_cycles() - d.flex.reconfig_cycles, best_sum);
}

#[test]
fn shipped_configs_load_and_simulate() {
    // Every TOML in configs/ must parse, validate, and drive a simulation.
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut found = 0;
    for entry in std::fs::read_dir(dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        found += 1;
        let cfg = ArchConfig::from_toml_file(&path).unwrap();
        cfg.validate().unwrap();
        let stats = simulate_network(&cfg, &zoo::yolo_tiny(), Dataflow::Os, SimOptions::default());
        assert!(stats.total_cycles() > 0, "{}", path.display());
    }
    assert!(found >= 3, "expected >=3 shipped configs, found {found}");
}

#[test]
fn batching_preserves_flex_advantage() {
    // The Flex >= best-static property must hold for batched serving too.
    let arch = ArchConfig::square(32);
    let topo = zoo::alexnet();
    let opts = SimOptions {
        batch: 8,
        ..Default::default()
    };
    let d = FlexPipeline::new(arch).with_options(opts).deploy(&topo);
    for df in Dataflow::ALL {
        assert!(d.speedup_vs(df) >= 1.0, "{df}");
    }
}

#[test]
fn dse_pareto_front_contains_flex_points() {
    // At any fixed size, the Flex variant dominates its static siblings on
    // latency at equal area+CMU, so the latency/area front should feature
    // Flex designs (statics can only appear via the tiny area delta).
    use flex_tpu::coordinator::dse;
    let points = dse::sweep(&zoo::resnet18(), &[8, 32], SimOptions::default());
    let front = dse::pareto_latency_area(&points);
    let flex_on_front = front
        .iter()
        .filter(|&&i| matches!(points[i].variant, dse::DseVariant::Flex))
        .count();
    assert!(flex_on_front >= 1, "no flex point on the Pareto front");
}

#[test]
fn cli_binary_smoke() {
    // Drive the leader binary end-to-end (simulate/deploy/report/dse).
    let bin = env!("CARGO_BIN_EXE_flex-tpu");
    let run = |args: &[&str]| {
        let out = std::process::Command::new(bin)
            .args(args)
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .output()
            .expect("binary runs");
        assert!(
            out.status.success(),
            "{args:?}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    let sim = run(&["simulate", "--model", "alexnet", "--size", "8"]);
    assert!(sim.contains("alexnet on 8x8"));
    let dep = run(&["deploy", "--model", "yolo_tiny", "--size", "16"]);
    assert!(dep.contains("flex total"));
    let rep = run(&["report", "table2"]);
    assert!(rep.contains("32x32"));
    let dse = run(&["dse", "--model", "alexnet", "--sizes", "8,16"]);
    assert!(dse.contains("minimum-EDP design"));
    let val = run(&["validate", "--array", "3", "--cases", "5"]);
    assert!(val.contains("bit-exact"));
    // Config-file path.
    let cfg = run(&["simulate", "--model", "alexnet", "--config", "configs/edge_8x8.toml"]);
    assert!(cfg.contains("8x8"));
    // Unknown subcommand exits non-zero.
    let out = std::process::Command::new(bin)
        .arg("bogus")
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn address_streams_conserved_across_all_folds() {
    // Summing the dataflow generator's per-fold event counts over the whole
    // fold grid must reproduce the analytical plan's traffic counts (with
    // edge-fold padding removed, which the generator's range gating does —
    // so generator counts <= plan counts, equal when no padding).
    let arch = ArchConfig::square(4);
    property("multi-fold-conservation", 0xF01d, 15, |rng: &mut Rng| {
        let g = Gemm::new(
            rng.range_u64(1, 10),
            rng.range_u64(1, 10),
            rng.range_u64(1, 10),
        );
        for df in Dataflow::ALL {
            let plan = flex_tpu::sim::dataflow::plan(&g, &arch, df);
            let mut ifmap = 0u64;
            let mut filter = 0u64;
            let mut ofmap = 0u64;
            for fa in 0..plan.folds_a {
                for fb in 0..plan.folds_b {
                    let s = dataflow_gen::generate(&g, &arch, df, fa, fb);
                    ifmap += s.ifmap_reads.len() as u64;
                    filter += s.filter_reads.len() as u64;
                    ofmap += s.ofmap_writes.len() as u64;
                }
            }
            // Real (unpadded) element events:
            //   ofmap writes = M*N per K-fold pass that emits (OS: 1; WS/IS:
            //   one partial write per K-fold).
            let k_folds = match df {
                Dataflow::Os => 1,
                Dataflow::Ws => plan.folds_a,
                Dataflow::Is => plan.folds_b,
            };
            assert_eq!(ofmap, g.m * g.n * k_folds, "{df} ofmap {g:?}");
            // Generator never exceeds the padded-plan traffic.
            assert!(ifmap <= plan.traffic.ifmap_reads, "{df} ifmap");
            assert!(filter <= plan.traffic.filter_reads, "{df} filter");
            // And covers every real operand element at least once.
            assert!(ifmap >= g.m * g.k, "{df} ifmap coverage");
            assert!(filter >= g.k * g.n, "{df} filter coverage");
        }
    });
}
