//! Acceptance tests for the multi-chip sharding subsystem (ISSUE 2):
//!
//! 1. one-chip sharded simulation is **byte-identical** to the unsharded
//!    engine, at the layer level and through the whole sweep path;
//! 2. per-layer compute cycles are monotonically non-increasing in the
//!    chip count for every (dataflow, strategy) on compute-bound layers;
//! 3. joint (dataflow × shard strategy) selection is deterministic across
//!    thread counts and never loses to the single-chip selector;
//! 4. `sweep --chips 4` semantics: every zoo model reports a speedup vs
//!    one chip, and the interconnect model behaves sanely.

use flex_tpu::config::{ArchConfig, InterconnectConfig};
use flex_tpu::coordinator::partition::{select_joint, select_joint_parallel};
use flex_tpu::coordinator::sweep::{sweep_zoo, sweep_zoo_chip_grid, sweep_zoo_sharded};
use flex_tpu::sim::engine::{simulate_layer, SimOptions};
use flex_tpu::sim::parallel::ShapeCache;
use flex_tpu::sim::shard::{
    all_gather_cycles, simulate_layer_sharded, simulate_layer_sharded_cached, ShardStrategy,
};
use flex_tpu::sim::Dataflow;
use flex_tpu::topology::zoo;

#[test]
fn one_chip_sharding_is_byte_identical_per_layer() {
    let arch = ArchConfig::square(32);
    let opts = SimOptions::default();
    for topo in [zoo::resnet18(), zoo::mobilenet()] {
        for layer in &topo.layers {
            for df in Dataflow::ALL {
                let direct = simulate_layer(&arch, layer, df, opts);
                for strategy in ShardStrategy::ALL {
                    let sharded = simulate_layer_sharded(&arch, layer, df, strategy, 1, opts);
                    assert_eq!(sharded.chips, 1);
                    assert_eq!(sharded.comm_cycles, 0);
                    assert_eq!(sharded.per_chip, vec![direct.clone()]);
                    assert_eq!(sharded.total_cycles(), direct.total_cycles());
                }
            }
        }
    }
}

#[test]
fn one_chip_sharded_sweep_matches_pre_shard_sweep() {
    // `sweep --chips 1` must report exactly what the plain (PR-1) sweep
    // engine reports, model by model.
    let arch = ArchConfig::square(32);
    let opts = SimOptions::default();
    let plain = sweep_zoo(&arch, 1, opts);
    let sharded = sweep_zoo_sharded(&arch, 1, 1, opts);
    for (p, s) in plain.models.iter().zip(&sharded.models) {
        assert_eq!(p.model, s.model);
        assert_eq!(p.flex_cycles, s.flex_cycles, "{}", p.model);
        assert_eq!(p.flex_cycles, s.single_chip_cycles, "{}", p.model);
        let dataflows: Vec<Dataflow> = s.selection.per_layer.iter().map(|c| c.dataflow).collect();
        assert_eq!(dataflows, p.selection.per_layer, "{}", p.model);
    }
}

#[test]
fn compute_cycles_monotone_for_compute_bound_layers() {
    // The paper's configurations are compute-bound; splitting a layer over
    // more chips can never make its critical shard slower (communication
    // is accounted separately).
    let arch = ArchConfig::square(32);
    let opts = SimOptions::default();
    for topo in zoo::all_models() {
        for layer in &topo.layers {
            for df in Dataflow::ALL {
                for strategy in ShardStrategy::ALL {
                    let mut prev = u64::MAX;
                    for chips in [1u32, 2, 4, 8, 16] {
                        let s = simulate_layer_sharded(&arch, layer, df, strategy, chips, opts);
                        assert_eq!(s.stall_cycles, 0, "compute-bound setting");
                        assert!(
                            s.compute_cycles <= prev,
                            "{}/{} {df} {strategy} at {chips} chips: {} > {prev}",
                            topo.name,
                            layer.name,
                            s.compute_cycles
                        );
                        prev = s.compute_cycles;
                    }
                }
            }
        }
    }
}

#[test]
fn joint_selection_deterministic_across_thread_counts() {
    let arch = ArchConfig::square(32);
    let opts = SimOptions::default();
    for topo in [zoo::alexnet(), zoo::googlenet()] {
        let cache = ShapeCache::new();
        let want = select_joint(&arch, &topo, opts, 4, &cache);
        for threads in [2usize, 4, 8] {
            let cache = ShapeCache::new();
            let got = select_joint_parallel(&arch, &topo, opts, 4, threads, &cache);
            assert_eq!(want, got, "{} at {threads} threads", topo.name);
        }
    }
}

#[test]
fn four_chip_zoo_sweep_reports_speedups() {
    // The acceptance criterion behind `flex-tpu sweep --chips 4`: every
    // model gets a (dataflow, strategy) selection and a real speedup.
    let arch = ArchConfig::square(32);
    let sweep = sweep_zoo_sharded(&arch, 4, 2, SimOptions::default());
    assert_eq!(sweep.models.len(), 7);
    for m in &sweep.models {
        let slack = m.selection.per_layer.len() as u64 * arch.reconfig_cycles;
        assert!(
            m.flex_cycles <= m.single_chip_cycles + slack,
            "{} regressed: {} > {}",
            m.model,
            m.flex_cycles,
            m.single_chip_cycles
        );
        assert!(
            m.speedup_vs_single_chip() > 1.5,
            "{}: only {:.3}x at 4 chips",
            m.model,
            m.speedup_vs_single_chip()
        );
    }
    assert!(sweep.cache.hits > 0, "{:?}", sweep.cache);
}

#[test]
fn chip_grid_speedup_grows_with_chip_count() {
    let arch = ArchConfig::square(32);
    let (results, _cache) = sweep_zoo_chip_grid(&arch, &[1, 2, 4], 2, SimOptions::default());
    assert_eq!(results.len(), 3);
    // Mean speedup over the zoo must not shrink as chips are added (the
    // joint selector can always fall back to fewer effective shards).
    let mut prev = 0.0f64;
    for r in &results {
        let total: f64 = r.models.iter().map(|m| m.speedup_vs_single_chip()).sum();
        let mean = total / r.models.len() as f64;
        assert!(
            mean >= prev - 1e-9,
            "mean speedup shrank at {} chips: {mean} < {prev}",
            r.chips
        );
        prev = mean;
    }
    assert!(prev > 2.0, "4-chip mean speedup only {prev:.3}");
}

#[test]
fn interconnect_cost_scales_with_bandwidth_and_latency() {
    let fast = InterconnectConfig {
        link_latency_cycles: 0,
        link_bytes_per_cycle: 4096,
    };
    let slow = InterconnectConfig {
        link_latency_cycles: 1000,
        link_bytes_per_cycle: 1,
    };
    assert!(all_gather_cycles(1 << 20, 4, &fast) < all_gather_cycles(1 << 20, 4, &slow));
    assert_eq!(all_gather_cycles(1 << 20, 1, &slow), 0);

    // A slower link shifts the joint selector away from communicating
    // strategies — flex cycles can only get worse, never better.
    let mut arch_fast = ArchConfig::square(32);
    arch_fast.interconnect = fast;
    let mut arch_slow = ArchConfig::square(32);
    arch_slow.interconnect = slow;
    let topo = zoo::resnet18();
    let opts = SimOptions::default();
    let cache_fast = ShapeCache::new();
    let cache_slow = ShapeCache::new();
    let sel_fast = select_joint(&arch_fast, &topo, opts, 4, &cache_fast);
    let sel_slow = select_joint(&arch_slow, &topo, opts, 4, &cache_slow);
    assert!(sel_fast.flex_layer_cycles() <= sel_slow.flex_layer_cycles());
}

#[test]
fn cached_and_uncached_sharding_agree_through_sweep_scale() {
    let arch = ArchConfig::square(16);
    let opts = SimOptions::default();
    let cache = ShapeCache::new();
    let topo = zoo::vgg13();
    for layer in &topo.layers {
        for df in Dataflow::ALL {
            for strategy in ShardStrategy::ALL {
                for chips in [2u32, 4] {
                    let direct = simulate_layer_sharded(&arch, layer, df, strategy, chips, opts);
                    let cached = simulate_layer_sharded_cached(
                        &arch,
                        layer,
                        df,
                        strategy,
                        chips,
                        opts,
                        &cache,
                    );
                    assert_eq!(direct, cached, "{} {df} {strategy} {chips}", layer.name);
                }
            }
        }
    }
    assert!(cache.stats().hit_rate() > 0.0, "{:?}", cache.stats());
}

#[test]
fn batch_split_across_chips_speeds_up_serving_batches() {
    // The serve_concurrent lever: a batch of 8 split over 4 chips.
    let arch = ArchConfig::square(32);
    let opts = SimOptions {
        batch: 8,
        ..SimOptions::default()
    };
    let topo = zoo::alexnet();
    let mut one = 0u64;
    let mut four = 0u64;
    for layer in &topo.layers {
        one += simulate_layer_sharded(&arch, layer, Dataflow::Os, ShardStrategy::Batch, 1, opts)
            .total_cycles();
        four += simulate_layer_sharded(&arch, layer, Dataflow::Os, ShardStrategy::Batch, 4, opts)
            .total_cycles();
    }
    assert!(four < one, "batch sharding did not help: {four} >= {one}");
    // Each chip runs a batch-2 slice; the whole-batch latency must beat
    // running the full batch on one chip but can never beat a lone batch-2
    // run (the composition takes a max, it does not invent speed).
    let batch2 = SimOptions {
        batch: 2,
        ..SimOptions::default()
    };
    let mut lone = 0u64;
    for layer in &topo.layers {
        lone += simulate_layer(&arch, layer, Dataflow::Os, batch2).total_cycles();
    }
    assert_eq!(four, lone, "4-way split of batch 8 is four batch-2 chips");
}
