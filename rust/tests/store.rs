//! Acceptance tests for the persisted plan/shape store (ISSUE 3 + 4):
//!
//! 1. **warm start end-to-end** — a second sweep against the same store
//!    preloads every shape entry, reports a hit rate of exactly 1.0 with
//!    zero misses (i.e. zero `simulate_layer` calls for cached shapes),
//!    and produces byte-identical results;
//! 2. **robustness** — truncated, corrupt, wrong-schema-version and
//!    wrong-provenance store files are silently ignored (cold start),
//!    never panic, and are repaired by the next write;
//! 3. plans round-trip through the store keyed by provenance;
//! 4. **concurrent writers** — interleaved writers sharing one store dir
//!    (threads here; processes differ only by pid in the temp-file name)
//!    never error and never leave a torn document behind.

use std::path::PathBuf;
use std::sync::Arc;

use flex_tpu::bench::{self, TuneSpec, TunedConfig, TUNED_CONFIG_KIND};
use flex_tpu::config::ArchConfig;
use flex_tpu::coordinator::plan::{compile_plan, provenance_key, ExecutionPlan};
use flex_tpu::coordinator::sweep::{sweep_models, sweep_zoo_stored};
use flex_tpu::inference::{ModelRegistry, SchedulePolicy, SimBackend};
use flex_tpu::sim::engine::SimOptions;
use flex_tpu::sim::parallel::ShapeCache;
use flex_tpu::sim::store::DocSource;
use flex_tpu::sim::PlanStore;
use flex_tpu::topology::zoo;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("flex-tpu-store-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn warm_start_hits_every_shape_and_is_byte_identical() {
    let dir = tmpdir("warm");
    let store = PlanStore::open(&dir).unwrap();
    let arch = ArchConfig::square(16);
    let opts = SimOptions::default();
    let models = vec![zoo::alexnet(), zoo::mobilenet(), zoo::resnet18()];
    let provenance = provenance_key(&arch, &models, opts, 1);

    let cold_cache = ShapeCache::new();
    assert_eq!(store.load_shapes(&provenance, &cold_cache), 0, "store starts empty");
    let cold = sweep_models(&arch, &models, 2, opts, &cold_cache);
    assert!(cold.cache.misses > 0, "cold run must simulate");
    store.save_shapes(&provenance, &cold_cache).unwrap();

    let warm_cache = ShapeCache::new();
    let loaded = store.load_shapes(&provenance, &warm_cache);
    assert_eq!(loaded as u64, cold_cache.stats().entries);
    for threads in [1usize, 4] {
        let warm = sweep_models(&arch, &models, threads, opts, &warm_cache);
        assert_eq!(cold.models, warm.models, "warm sweep diverged at {threads} threads");
    }
    let stats = warm_cache.stats();
    assert_eq!(stats.misses, 0, "warm start must do zero simulate_layer calls: {stats:?}");
    assert!(stats.hits > 0);
    assert_eq!(stats.hit_rate(), 1.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sweep_zoo_stored_round_trip() {
    let dir = tmpdir("zoo");
    let store = PlanStore::open(&dir).unwrap();
    let arch = ArchConfig::square(8);
    let opts = SimOptions::default();
    let (cold, loaded_cold) = sweep_zoo_stored(&arch, 2, opts, Some(&store)).unwrap();
    assert_eq!(loaded_cold, 0);
    let (warm, loaded_warm) = sweep_zoo_stored(&arch, 2, opts, Some(&store)).unwrap();
    assert!(loaded_warm > 0, "second run must load persisted state");
    assert_eq!(cold.models, warm.models, "warm zoo sweep must be byte-identical");
    assert_eq!(warm.cache.misses, 0, "warm zoo sweep must not simulate: {:?}", warm.cache);
    assert_eq!(warm.cache.hit_rate(), 1.0);
    // Without a store the same call still works (cold every time).
    let (plain, loaded_plain) = sweep_zoo_stored(&arch, 2, opts, None).unwrap();
    assert_eq!(loaded_plain, 0);
    assert_eq!(plain.models, cold.models);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn plan_store_round_trip_keyed_by_provenance() {
    let dir = tmpdir("plan");
    let store = PlanStore::open(&dir).unwrap();
    let arch = ArchConfig::square(16);
    let opts = SimOptions::default();
    let cache = ShapeCache::new();
    let plan = compile_plan(&arch, &zoo::yolo_tiny(), opts, 4, &cache);
    assert!(ExecutionPlan::load(&store, &plan.provenance).is_none(), "store starts cold");
    plan.save(&store).unwrap();
    let back = ExecutionPlan::load(&store, &plan.provenance).unwrap();
    assert_eq!(plan, back);
    assert!(ExecutionPlan::load(&store, "0000000000000000").is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn interleaved_writers_never_corrupt_the_store() {
    // Two distinct complete snapshots of the same provenance key: a
    // 1-entry cache and a full-topology cache.  Writers race to persist
    // them; every save must succeed (no shared temp files to rename out
    // from under each other) and every load — concurrent or final — must
    // observe one of the two complete versions, never a torn mix.
    let dir = tmpdir("interleave");
    let store = PlanStore::open(&dir).unwrap();
    let arch = ArchConfig::square(8);
    let opts = SimOptions::default();
    let topo = zoo::alexnet();

    let small = ShapeCache::new();
    small.simulate_layer(&arch, &topo.layers[0], flex_tpu::sim::Dataflow::Os, opts);
    let big = ShapeCache::new();
    for layer in &topo.layers {
        for df in flex_tpu::sim::Dataflow::ALL {
            big.simulate_layer(&arch, layer, df, opts);
        }
    }
    let n_small = small.stats().entries as usize;
    let n_big = big.stats().entries as usize;
    assert!(n_small < n_big);

    const WRITERS: usize = 4;
    const READERS: usize = 2;
    const ITERS: usize = 40;
    std::thread::scope(|scope| {
        for w in 0..WRITERS {
            let store = store.clone();
            let cache = if w % 2 == 0 { &small } else { &big };
            scope.spawn(move || {
                for i in 0..ITERS {
                    store
                        .save_shapes("race", cache)
                        .unwrap_or_else(|e| panic!("writer {w} iter {i}: {e}"));
                }
            });
        }
        for r in 0..READERS {
            let store = store.clone();
            scope.spawn(move || {
                for i in 0..ITERS {
                    let warm = ShapeCache::new();
                    let loaded = store.load_shapes("race", &warm);
                    assert!(
                        loaded == 0 || loaded == n_small || loaded == n_big,
                        "reader {r} iter {i}: torn read of {loaded} entries \
                         (expected 0, {n_small} or {n_big})"
                    );
                }
            });
        }
    });

    // The final document is complete and valid, and no temp litter stays
    // behind to be mistaken for state.
    let warm = ShapeCache::new();
    let final_loaded = store.load_shapes("race", &warm);
    assert!(final_loaded == n_small || final_loaded == n_big);
    let tmp_litter: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().to_str().map(str::to_string))
        .filter(|n| n.contains(".tmp."))
        .collect();
    assert!(tmp_litter.is_empty(), "temp files left behind: {tmp_litter:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn compact_keeps_live_tuned_configs_and_drops_unknown_ones() {
    // The PR-7 extension of the gc contract: `tuned-config` records are
    // pruned exactly like plans and shapes — live provenances survive,
    // unknown ones are dropped.
    let dir = tmpdir("tuned-gc");
    let store = PlanStore::open(&dir).unwrap();
    let live = TunedConfig {
        config: "tune;live".to_string(),
        batch: 2,
        policy: "deadline-edf".to_string(),
        feasible: true,
        throughput_rps: 100.0,
        goodput_rps: 90.0,
        admission: [("alexnet".to_string(), 4usize)].into_iter().collect(),
        priorities: [("alexnet".to_string(), 0u8)].into_iter().collect(),
        expected_mix: [("alexnet".to_string(), 60u64)].into_iter().collect(),
    };
    let mut stale = live.clone();
    stale.config = "tune;stale".to_string();
    stale.batch = 8;
    live.save(&store, "feedfacefeedface").unwrap();
    stale.save(&store, "deadbeefdeadbeef").unwrap();
    assert_eq!(store.list_kind(TUNED_CONFIG_KIND).len(), 2);

    let stats = store.compact(&["feedfacefeedface".to_string()]).unwrap();
    assert_eq!(stats.kept, 1);
    assert_eq!(stats.dropped_unknown, 1);

    let left = store.list_kind(TUNED_CONFIG_KIND);
    assert_eq!(left.len(), 1);
    assert_eq!(left[0].0, "feedfacefeedface");
    assert_eq!(TunedConfig::load(&store, "feedfacefeedface").unwrap(), live);
    assert!(TunedConfig::load(&store, "deadbeefdeadbeef").is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_restart_after_gc_loads_tuned_config_with_zero_sweeps() {
    // gc down to the live tuned record, then restart: the tuner must
    // warm-load it with zero sweep re-simulation (the PR-7 warm-restart
    // acceptance criterion, post-compaction).
    let dir = tmpdir("tuned-warm-gc");
    let store = PlanStore::open(&dir).unwrap();
    let models = ["alexnet", "mobilenet"];
    let make = |batch: u32| -> flex_tpu::error::Result<Arc<ModelRegistry>> {
        let registry = ModelRegistry::new(ArchConfig::square(16), Some(store.clone()))?;
        for name in models {
            registry.register(Arc::new(SimBackend::from_zoo(name, batch)?))?;
        }
        Ok(Arc::new(registry))
    };
    let mut spec = TuneSpec::new(models.iter().map(|s| s.to_string()).collect());
    spec.requests = 120;
    spec.deadline_us = None;
    spec.batch_candidates = vec![1, 2];
    spec.policy_candidates = vec![SchedulePolicy::Fifo];

    let reference = make(1).unwrap();
    let cold = bench::tune_or_load(Some(&store), &reference, &make, &spec).unwrap();
    assert_eq!(cold.source, DocSource::Computed);
    assert_eq!(cold.sweeps, 2, "2 batches x 1 policy");

    // Compact down to the tuned record alone (plans and shapes of the
    // sweep registries are deliberately left for dead here).
    let stats = store.compact(&[reference.tuned_provenance()]).unwrap();
    assert_eq!(stats.kept, 1, "the live tuned config survives");
    assert!(stats.dropped_unknown > 0, "sweep plans/shapes were pruned");
    let left = store.list_kind(TUNED_CONFIG_KIND);
    assert_eq!(left.len(), 1);
    assert_eq!(left[0].0, reference.tuned_provenance());

    // A fresh restart over the compacted store warm-loads the config.
    let restarted = make(1).unwrap();
    let warm = bench::tune_or_load(Some(&store), &restarted, &make, &spec).unwrap();
    assert_eq!(warm.source, DocSource::Loaded);
    assert_eq!(warm.sweeps, 0, "warm restart must not re-sweep");
    assert_eq!(warm.tuned, cold.tuned);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_store_files_read_cold_and_are_repaired() {
    let dir = tmpdir("corrupt");
    let store = PlanStore::open(&dir).unwrap();
    let arch = ArchConfig::square(8);
    let opts = SimOptions::default();
    let topo = zoo::alexnet();
    let models = vec![topo.clone()];
    let provenance = provenance_key(&arch, &models, opts, 1);

    // Produce one good file so we can derive a truncated variant of it.
    let cache = ShapeCache::new();
    let plan = compile_plan(&arch, &topo, opts, 1, &cache);
    store.save_shapes(&provenance, &cache).unwrap();
    plan.save(&store).unwrap();
    let shapes_path = dir.join(format!("shapes-{provenance}.json"));
    let plan_path = dir.join(format!("plan-{}.json", plan.provenance));
    let good_shapes = std::fs::read_to_string(&shapes_path).unwrap();
    let good_plan = std::fs::read_to_string(&plan_path).unwrap();

    let wrong_schema = good_shapes.replacen("\"schema\": 1", "\"schema\": 999", 1);
    let wrong_prov = good_shapes.replacen(&provenance, "deadbeefdeadbeef", 2);
    let cases: Vec<(&str, String)> = vec![
        ("empty", String::new()),
        ("truncated", good_shapes[..good_shapes.len() / 2].to_string()),
        ("not json", "{{{ not json at all".to_string()),
        ("wrong type", "[1, 2, 3]".to_string()),
        ("wrong schema", wrong_schema),
        ("wrong provenance", wrong_prov),
    ];
    for (what, bad) in &cases {
        std::fs::write(&shapes_path, bad).unwrap();
        let fresh = ShapeCache::new();
        assert_eq!(
            store.load_shapes(&provenance, &fresh),
            0,
            "{what} shapes file must read cold"
        );
        std::fs::write(&plan_path, bad).unwrap();
        assert!(
            ExecutionPlan::load(&store, &plan.provenance).is_none(),
            "{what} plan file must read cold"
        );
    }

    // The next write repairs both files wholesale.
    store.save_shapes(&provenance, &cache).unwrap();
    plan.save(&store).unwrap();
    let fresh = ShapeCache::new();
    assert!(store.load_shapes(&provenance, &fresh) > 0, "repaired shapes load");
    assert_eq!(ExecutionPlan::load(&store, &plan.provenance).unwrap(), plan);
    assert_eq!(std::fs::read_to_string(&shapes_path).unwrap(), good_shapes);
    assert_eq!(std::fs::read_to_string(&plan_path).unwrap(), good_plan);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn compacted_store_still_warm_starts_at_full_hit_rate() {
    // The ISSUE-5 gc contract: prune everything that is not live, then
    // prove the survivors still warm-start exactly — plans load, shapes
    // preload, hit rate 1.0 with zero simulate_layer calls.
    let dir = tmpdir("compact-warm");
    let store = PlanStore::open(&dir).unwrap();
    let opts = SimOptions::default();
    let live_arch = ArchConfig::square(16);
    let stale_arch = ArchConfig::square(8);

    // Live + stale artifacts for the same models at two array sizes.
    let mut live_keys = Vec::new();
    for arch in [live_arch, stale_arch] {
        for topo in [zoo::alexnet(), zoo::mobilenet()] {
            let provenance = provenance_key(&arch, std::slice::from_ref(&topo), opts, 1);
            let cache = ShapeCache::new();
            let plan = compile_plan(&arch, &topo, opts, 1, &cache);
            plan.save(&store).unwrap();
            store.save_shapes(&provenance, &cache).unwrap();
            if arch == live_arch {
                live_keys.push(provenance);
            }
        }
    }
    // Corrupt litter on top, plus an abandoned staged write (backdated —
    // compact leaves *fresh* temp files for their live writers).
    std::fs::write(dir.join("plan-00ff.json"), "{torn").unwrap();
    let tmp = dir.join(".shapes-x.tmp.9.9");
    std::fs::write(&tmp, "staged").unwrap();
    std::fs::OpenOptions::new()
        .write(true)
        .open(&tmp)
        .unwrap()
        .set_modified(std::time::SystemTime::now() - std::time::Duration::from_secs(7200))
        .unwrap();

    let stats = store.compact(&live_keys).unwrap();
    assert_eq!(stats.kept, 4, "2 live plans + 2 live shape docs");
    assert_eq!(stats.dropped_unknown, 4, "stale-size plans + shapes");
    assert_eq!(stats.dropped_invalid, 1);
    assert_eq!(stats.tmp_removed, 1);

    // Survivors warm-start exactly as before the gc.
    for topo in [zoo::alexnet(), zoo::mobilenet()] {
        let provenance = provenance_key(&live_arch, std::slice::from_ref(&topo), opts, 1);
        let warm = ShapeCache::new();
        assert!(store.load_shapes(&provenance, &warm) > 0, "{}", topo.name);
        let stored = ExecutionPlan::load(&store, &provenance).expect("live plan survives");
        let recompiled = compile_plan(&live_arch, &topo, opts, 1, &warm);
        assert_eq!(stored, recompiled, "{}", topo.name);
        let s = warm.stats();
        assert_eq!(s.misses, 0, "{}: compact broke the warm start: {s:?}", topo.name);
        assert_eq!(s.hit_rate(), 1.0, "{}", topo.name);
    }
    // The stale size reads cold now.
    let cold = ShapeCache::new();
    let stale_prov =
        provenance_key(&stale_arch, std::slice::from_ref(&zoo::alexnet()), opts, 1);
    assert_eq!(store.load_shapes(&stale_prov, &cold), 0);
    assert!(ExecutionPlan::load(&store, &stale_prov).is_none());
    let _ = std::fs::remove_dir_all(&dir);
}
