//! Golden-file regression tests for the report layer (ISSUE 4).
//!
//! The rendered Table I / Table II text for the seed configurations is
//! committed under `tests/golden/`; any drift in the cycle model, the
//! selection tie-break, the cost calibration or the table renderer fails
//! these tests loudly instead of silently shifting the paper numbers.
//!
//! To bless an *intentional* model change, regenerate with
//! `FLEX_TPU_UPDATE_GOLDEN=1 cargo test --test golden` and commit the
//! diff — the diff itself then documents the drift for review.

use std::path::PathBuf;

use flex_tpu::report;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(name)
}

/// Compare `actual` against the committed golden file, with a first-diff
/// pointer in the failure message.  `FLEX_TPU_UPDATE_GOLDEN=1` rewrites
/// the file instead (the "bless" flow).
fn check_golden(name: &str, actual: &str) {
    let path = golden_path(name);
    if std::env::var_os("FLEX_TPU_UPDATE_GOLDEN").is_some() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, actual).unwrap();
        return;
    }
    let expected = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("golden file {} unreadable: {e}", path.display()));
    if expected == actual {
        return;
    }
    let diff_line = expected
        .lines()
        .zip(actual.lines())
        .position(|(e, a)| e != a)
        .map(|i| i + 1)
        .unwrap_or_else(|| expected.lines().count().min(actual.lines().count()) + 1);
    panic!(
        "{name}: rendered output drifted from the committed golden \
         (first difference at line {diff_line}).\n\
         If the cycle/cost model changed intentionally, regenerate with \
         FLEX_TPU_UPDATE_GOLDEN=1 and commit the diff.\n\
         === expected ===\n{expected}\n=== actual ===\n{actual}"
    );
}

#[test]
fn table1_8x8_matches_golden() {
    check_golden("table1_8x8.txt", &report::table1(8).render());
}

#[test]
fn table1_32x32_matches_golden() {
    check_golden("table1_32x32.txt", &report::table1(32).render());
}

#[test]
fn table2_matches_golden() {
    check_golden("table2.txt", &report::table2().render());
}

#[test]
fn goldens_are_committed() {
    if std::env::var_os("FLEX_TPU_UPDATE_GOLDEN").is_some() {
        // Bless mode rewrites the files concurrently with this test in
        // the same binary; checking mid-rewrite would race a torn read.
        return;
    }
    // The bless flow must never leave the tree without its goldens: all
    // three files exist and are non-trivial.
    for name in ["table1_8x8.txt", "table1_32x32.txt", "table2.txt"] {
        let text = std::fs::read_to_string(golden_path(name))
            .unwrap_or_else(|e| panic!("{name} missing: {e}"));
        assert!(text.lines().count() >= 5, "{name} suspiciously short");
        assert!(text.ends_with('\n'), "{name} must end with a newline");
    }
}
