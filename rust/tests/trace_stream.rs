//! Acceptance tests for the streaming trace pipeline (ISSUE 8).
//!
//! The driver no longer owns a `Vec<TraceEvent>`: it pulls arrivals off a
//! lazy iterator through a one-event peek window.  These tests pin the
//! two contracts that switch rests on:
//!
//! 1. **iterator ≡ collected trace** — `TraceSpec::events` replays the
//!    exact LCG draw sequence of the collecting `generate`, is
//!    `ExactSizeIterator`-honest, and is deterministic per spec;
//! 2. **driver byte identity** — `bench::run` (which streams) and
//!    `bench::run_with_trace` fed the same trace as an owned `Vec`
//!    serialize to byte-identical `BenchReport` JSON, open and closed
//!    loop, so streaming can never change a gated number.

use std::sync::Arc;

use flex_tpu::bench::trace::generate;
use flex_tpu::bench::{run, run_with_trace, BenchConfig, LoopMode, Scenario, TraceSpec};
use flex_tpu::config::ArchConfig;
use flex_tpu::inference::{ModelRegistry, SchedulePolicy, SimBackend};

const MODELS: [&str; 3] = ["alexnet", "resnet18", "vgg13"];

fn registry() -> Arc<ModelRegistry> {
    let registry = ModelRegistry::new(ArchConfig::square(64), None).unwrap();
    for name in MODELS {
        registry
            .register(Arc::new(SimBackend::from_zoo(name, 4).unwrap()))
            .unwrap();
    }
    Arc::new(registry)
}

fn config() -> BenchConfig {
    BenchConfig {
        scenario: Scenario::MixedModel,
        seed: 7,
        requests: 400,
        mean_interarrival_us: 2_000,
        models: MODELS.iter().map(|s| s.to_string()).collect(),
        policy: SchedulePolicy::Fifo,
        mode: LoopMode::Open,
        concurrency: 32,
        deadline_us: None,
        admission: std::collections::BTreeMap::new(),
        priorities: std::collections::BTreeMap::new(),
        overload_control: false,
        seq: None,
    }
}

/// The trace `bench::run` derives from a config (same construction as the
/// driver's own).
fn spec_of(cfg: &BenchConfig) -> TraceSpec {
    TraceSpec {
        scenario: cfg.scenario,
        seed: cfg.seed,
        requests: cfg.requests,
        models: cfg.models.len(),
        mean_interarrival_us: cfg.mean_interarrival_us,
        seq: None,
    }
}

#[test]
fn iterator_collects_to_exactly_the_generated_trace() {
    for scenario in Scenario::ALL {
        for seed in 0..25u64 {
            for requests in [0u64, 1, 17, 400] {
                let spec = TraceSpec {
                    scenario,
                    seed,
                    requests,
                    models: 3,
                    mean_interarrival_us: 1_500,
                    seq: None,
                };
                let collected: Vec<_> = spec.events().collect();
                assert_eq!(
                    collected,
                    generate(&spec),
                    "{scenario} seed {seed} n {requests}"
                );
                // Two independent iterators replay the same draw sequence.
                assert!(
                    spec.events().eq(spec.events()),
                    "{scenario} seed {seed} n {requests}: iterator not deterministic"
                );
                assert_eq!(collected.len() as u64, requests, "{scenario} seed {seed}");
            }
        }
    }
}

#[test]
fn iterator_is_exact_size_and_well_formed() {
    let spec = TraceSpec {
        scenario: Scenario::Bursty,
        seed: 11,
        requests: 300,
        models: 4,
        mean_interarrival_us: 2_000,
        seq: None,
    };
    let mut it = spec.events();
    assert_eq!(it.len(), 300);
    let mut last_at = 0u64;
    for expect_id in 0..300u64 {
        assert_eq!(it.size_hint(), (300 - expect_id as usize, Some(300 - expect_id as usize)));
        let e = it.next().unwrap();
        assert_eq!(e.id, expect_id, "ids are arrival-ordered");
        assert!(e.model < 4);
        assert!(e.at_us >= last_at, "time monotone");
        last_at = e.at_us;
    }
    assert_eq!(it.len(), 0);
    assert_eq!(it.next(), None);
    // Exhausted iterators stay exhausted.
    assert_eq!(it.next(), None);
}

#[test]
fn driver_reports_are_byte_identical_for_vec_and_iterator_input() {
    let reg = registry();
    for (mode, policy) in [
        (LoopMode::Open, SchedulePolicy::Fifo),
        (LoopMode::Open, SchedulePolicy::ReconfigAware),
        (LoopMode::Open, SchedulePolicy::DeadlineEdf),
        (LoopMode::Closed, SchedulePolicy::Fifo),
        (LoopMode::Closed, SchedulePolicy::ReconfigAware),
    ] {
        let mut cfg = config();
        cfg.mode = mode;
        cfg.policy = policy;
        if policy == SchedulePolicy::DeadlineEdf {
            cfg.deadline_us = Some(2_000_000);
        }
        let spec = spec_of(&cfg);
        let streamed = run(&reg, &cfg).unwrap().to_json().to_string();
        let from_vec = run_with_trace(&reg, &cfg, generate(&spec))
            .unwrap()
            .to_json()
            .to_string();
        let from_iter = run_with_trace(&reg, &cfg, spec.events())
            .unwrap()
            .to_json()
            .to_string();
        assert_eq!(streamed, from_vec, "{mode:?}/{policy:?}: Vec input diverged");
        assert_eq!(streamed, from_iter, "{mode:?}/{policy:?}: iterator input diverged");
    }
}
