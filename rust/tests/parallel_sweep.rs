//! Acceptance tests for the parallel sweep subsystem (ISSUE 1):
//!
//! 1. full-zoo exhaustive selection on >= 2 threads is **byte-identical**
//!    to the single-threaded path (selections, cycle rows, totals);
//! 2. the `ShapeCache` hit-rate over the zoo is reported and > 0;
//! 3. every caller that was threaded through the engine (selector, dse,
//!    report/table1) produces the same numbers at any thread count.

use std::sync::Arc;

use flex_tpu::config::ArchConfig;
use flex_tpu::coordinator::dse;
use flex_tpu::coordinator::selector::{select_exhaustive, select_exhaustive_parallel};
use flex_tpu::coordinator::sweep::{sweep_models, sweep_zoo};
use flex_tpu::report;
use flex_tpu::sim::engine::SimOptions;
use flex_tpu::sim::parallel::{parallel_map, ShapeCache};
use flex_tpu::topology::zoo;

#[test]
fn zoo_selection_byte_identical_across_thread_counts() {
    let arch = ArchConfig::square(32);
    let opts = SimOptions::default();
    let serial = sweep_zoo(&arch, 1, opts);
    for threads in [2usize, 4] {
        let parallel = sweep_zoo(&arch, threads, opts);
        assert_eq!(
            serial.models, parallel.models,
            "{threads}-thread sweep diverged from serial"
        );
    }
}

#[test]
fn per_model_parallel_selector_matches_serial() {
    let arch = ArchConfig::square(32);
    let opts = SimOptions::default();
    for topo in zoo::all_models() {
        let want = select_exhaustive(&arch, &topo, opts);
        for threads in [2usize, 4] {
            let cache = ShapeCache::new();
            let got = select_exhaustive_parallel(&arch, &topo, opts, threads, &cache);
            assert_eq!(want, got, "{} at {threads} threads", topo.name);
        }
    }
}

#[test]
fn zoo_sweep_reports_positive_cache_hit_rate() {
    let sweep = sweep_zoo(&ArchConfig::square(32), 4, SimOptions::default());
    let stats = sweep.cache;
    assert!(stats.hits + stats.misses > 0, "cache saw no lookups");
    assert!(
        stats.hit_rate() > 0.0,
        "zoo has many repeated layer shapes; hit rate was 0 ({stats:?})"
    );
    // Every lookup is either a hit or a miss, and entries come from misses.
    assert!(stats.entries <= stats.misses);
    // The seven-model zoo repeats shapes heavily (residual blocks,
    // inception branches, dw/pw pairs): ~23% of lookups hit.  Concurrent
    // first-touches of a shape may double-compute (each counts as a miss),
    // so assert a bound safely below the race-free rate.
    assert!(
        stats.hit_rate() > 0.15,
        "suspiciously low reuse: {stats:?}"
    );
}

#[test]
fn shared_cache_across_models_hits_cross_model_shapes() {
    // vgg13 and faster_rcnn share conv shapes (both VGG-style trunks):
    // sweeping them with one cache must hit on the second model.
    let arch = ArchConfig::square(32);
    let opts = SimOptions::default();
    let cache = ShapeCache::new();
    let models = vec![zoo::vgg13(), zoo::faster_rcnn()];
    let result = sweep_models(&arch, &models, 2, opts, &cache);
    assert_eq!(result.models.len(), 2);
    assert!(result.cache.hits > 0, "{:?}", result.cache);
}

#[test]
fn dse_parallel_sweep_identical() {
    let topo = zoo::alexnet();
    let opts = SimOptions::default();
    let serial = dse::sweep(&topo, &[8, 16, 32], opts);
    let parallel = dse::sweep_parallel(&topo, &[8, 16, 32], opts, 4);
    assert_eq!(serial, parallel);
}

#[test]
fn table1_rows_identical_across_thread_counts() {
    let serial = report::table1_rows(16, SimOptions::default());
    let parallel = report::table1_rows_with(16, SimOptions::default(), 4);
    assert_eq!(serial, parallel);
}

#[test]
fn parallel_map_balances_skewed_work() {
    // Items with wildly uneven cost still all complete, in order, with
    // work-stealing keeping every index accounted for.
    let items: Vec<u64> = (0..64).map(|i| if i % 8 == 0 { 200_000 } else { 10 }).collect();
    let out = parallel_map(4, &items, |_, &spin| {
        let mut acc = 0u64;
        for i in 0..spin {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        spin
    });
    assert_eq!(out, items);
}

#[test]
fn parallel_sweep_consistent_with_pipeline_totals() {
    use flex_tpu::coordinator::FlexPipeline;
    use flex_tpu::sim::Dataflow;
    let arch = ArchConfig::square(32);
    let sweep = sweep_zoo(&arch, 4, SimOptions::default());
    for m in &sweep.models {
        let d = FlexPipeline::new(arch).deploy(&zoo::by_name(&m.model).unwrap());
        assert_eq!(m.flex_cycles, d.total_cycles(), "{}", m.model);
        for (i, df) in Dataflow::ALL.into_iter().enumerate() {
            assert_eq!(m.static_cycles[i], d.static_cycles(df), "{} {df}", m.model);
        }
    }
}

#[test]
fn cached_pipeline_deploy_identical_to_uncached() {
    use flex_tpu::coordinator::FlexPipeline;
    let arch = ArchConfig::square(16);
    let cache = Arc::new(ShapeCache::new());
    for topo in zoo::all_models() {
        let plain = FlexPipeline::new(arch).deploy(&topo);
        let cached = FlexPipeline::new(arch)
            .with_cache(Arc::clone(&cache))
            .deploy(&topo);
        assert_eq!(plain, cached, "{}", topo.name);
    }
    assert!(cache.stats().hits > 0, "{:?}", cache.stats());
}
