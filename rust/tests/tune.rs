//! Acceptance tests for the closed-loop autotuner + overload control
//! (ISSUE 7), the seeded property/oracle layer:
//!
//! 1. **reproducibility** — the tuned config is byte-identical for the
//!    same spec + seed across fresh (cold-cache) registries, over 3
//!    scenarios and a spread of seeds;
//! 2. **selection soundness** — the tuner is never SLO-infeasible when a
//!    feasible candidate exists, never selects worse throughput than the
//!    untuned default when their feasibility matches, and reports exactly
//!    the numbers its selected candidate measured;
//! 3. **accounting** — `served + dropped + rejected + shed == offered`
//!    closes (aggregate and per model) on every overload run;
//! 4. **degraded mode** — queued requests shed strictly by priority tier,
//!    newest first within a tier, across seeded queue depths;
//! 5. **warm start / drift** — a second `tune_or_load` against the same
//!    store loads with zero sweeps; a drifted trace mix re-tunes;
//! 6. **oracle + golden gate** — on the gated overload scenario the tuned
//!    overload posture beats plain `deadline-edf` goodput strictly, and a
//!    fresh [`TuneDoc`] passes `gate_tune` against the committed
//!    `tests/golden/tune_baseline.json` (bless intentional model changes
//!    with `FLEX_TPU_UPDATE_GOLDEN=1 cargo test --test tune`).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use flex_tpu::bench::{self, BenchConfig, BenchReport, Scenario, TuneDoc, TuneSpec, TunedConfig};
use flex_tpu::config::ArchConfig;
use flex_tpu::coordinator::plan::ReconfigForecast;
use flex_tpu::inference::{ModelProfile, ModelRegistry, SchedulePolicy, Scheduler, SimBackend};
use flex_tpu::sim::store::{DocSource, PlanStore};
use flex_tpu::sim::Dataflow;
use flex_tpu::util::json::parse;

/// The gated configuration: what CI's `perf` job runs via `flex-tpu tune`
/// and what the committed baseline stores.  Same models/array as the
/// bench baseline; the gated trace genuinely overloads this registry
/// (plain `deadline-edf` drops ~half of it), which is what makes the
/// goodput oracle meaningful.
const GATED_MODELS: [&str; 3] = ["alexnet", "resnet18", "vgg13"];
const GATED_SIZE: u32 = 128;

/// The property arena: a small array and cheap models so the seeded
/// sweeps stay fast.
const PROP_MODELS: [&str; 3] = ["alexnet", "mobilenet", "resnet18"];
const PROP_SIZE: u32 = 32;
const PROP_REQUESTS: u64 = 120;
const PROP_BATCHES: [u32; 3] = [1, 2, 4];

fn registry(size: u32, batch: u32, models: &[&str]) -> Arc<ModelRegistry> {
    let registry = ModelRegistry::new(ArchConfig::square(size), None).unwrap();
    for name in models {
        registry
            .register(Arc::new(SimBackend::from_zoo(name, batch).unwrap()))
            .unwrap();
    }
    Arc::new(registry)
}

fn prop_models() -> Vec<String> {
    PROP_MODELS.iter().map(|s| s.to_string()).collect()
}

/// One property-arena registry per candidate batch size.
fn prop_registries() -> BTreeMap<u32, Arc<ModelRegistry>> {
    PROP_BATCHES
        .iter()
        .map(|&b| (b, registry(PROP_SIZE, b, &PROP_MODELS)))
        .collect()
}

/// Mean per-request service time (µs) of the arena under this trace,
/// probed with a deadline-free back-to-back run.  The overload specs
/// below are calibrated relative to it so the properties do not bake in
/// absolute cycle counts.
fn probe_avg_service_us(
    regs: &BTreeMap<u32, Arc<ModelRegistry>>,
    scenario: Scenario,
    seed: u64,
) -> u64 {
    let cfg = BenchConfig::builder(prop_models())
        .scenario(scenario)
        .seed(seed)
        .requests(PROP_REQUESTS)
        .mean_interarrival_us(1)
        .policy(SchedulePolicy::Fifo)
        .build();
    let r = bench::run(&regs[&2], &cfg).unwrap();
    ((r.sim_wall_us / PROP_REQUESTS as f64) as u64).max(1)
}

/// A deliberately overloaded tuning spec: arrivals ~4x faster than the
/// arena can serve, deadlines ~3 mean service times, so deadline pressure
/// (and candidate infeasibility) is real.
fn tight_spec(scenario: Scenario, seed: u64, avg_us: u64) -> TuneSpec {
    let mut spec = TuneSpec::new(prop_models());
    spec.scenario = scenario;
    spec.seed = seed;
    spec.requests = PROP_REQUESTS;
    spec.mean_interarrival_us = (avg_us / 4).max(1);
    spec.deadline_us = Some((avg_us * 3).max(1));
    spec.batch_candidates = PROP_BATCHES.to_vec();
    spec
}

/// Run one candidate of `spec`'s sweep grid independently of the tuner.
fn candidate_report(
    regs: &BTreeMap<u32, Arc<ModelRegistry>>,
    spec: &TuneSpec,
    batch: u32,
    policy: SchedulePolicy,
) -> BenchReport {
    let cfg = BenchConfig::builder(spec.models.clone())
        .scenario(spec.scenario)
        .seed(spec.seed)
        .requests(spec.requests)
        .mean_interarrival_us(spec.mean_interarrival_us)
        .policy(policy)
        .mode(spec.mode)
        .concurrency(spec.concurrency)
        .deadline_us(spec.deadline_us)
        .build();
    bench::run(&regs[&batch], &cfg).unwrap()
}

/// The tuner's feasibility rule, restated independently.
fn feasible(spec: &TuneSpec, r: &BenchReport) -> bool {
    r.dropped_deadline == 0
        && r.rejected == 0
        && r.shed == 0
        && (spec.deadline_us.is_none() || r.slo_met == r.served)
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("flex-tpu-tune-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn tuned_config_is_byte_reproducible_across_fresh_registries() {
    let shared = prop_registries();
    for scenario in Scenario::ALL {
        for seed in [1u64, 5, 9, 13, 17, 21, 25] {
            let avg = probe_avg_service_us(&shared, scenario, seed);
            let spec = tight_spec(scenario, seed, avg);
            // Each tune gets its own cold registries: nothing cache- or
            // host-dependent may leak into the selection.
            let tune_fresh = || {
                let regs = prop_registries();
                let factory = move |batch: u32| -> flex_tpu::error::Result<Arc<ModelRegistry>> {
                    Ok(Arc::clone(&regs[&batch]))
                };
                bench::tune::tune(&factory, &spec).unwrap()
            };
            let a = tune_fresh();
            let b = tune_fresh();
            assert_eq!(a, b, "{scenario:?} seed {seed}: tuned configs diverged");
            assert_eq!(
                a.to_json().to_string(),
                b.to_json().to_string(),
                "{scenario:?} seed {seed}: tuned config bytes diverged"
            );
            // A different seed is a different trace: the expected mix (at
            // minimum) must differ, so the configs cannot collide.
            let reseeded = tight_spec(scenario, seed + 1, avg);
            let factory = |batch: u32| -> flex_tpu::error::Result<Arc<ModelRegistry>> {
                Ok(Arc::clone(&shared[&batch]))
            };
            let c = bench::tune::tune(&factory, &reseeded).unwrap();
            assert_ne!(
                a.expected_mix, c.expected_mix,
                "{scenario:?} seeds {seed}/{} produced identical traces",
                seed + 1
            );
        }
    }
}

#[test]
fn tuner_is_feasible_when_possible_and_never_below_the_untuned_default() {
    let regs = prop_registries();
    let factory = |batch: u32| -> flex_tpu::error::Result<Arc<ModelRegistry>> {
        Ok(Arc::clone(&regs[&batch]))
    };
    for scenario in Scenario::ALL {
        for seed in [2u64, 6, 10, 14, 18, 22, 26] {
            let avg = probe_avg_service_us(&regs, scenario, seed);
            let spec = tight_spec(scenario, seed, avg);
            let tuned = bench::tune::tune(&factory, &spec).unwrap();
            let tag = format!("{scenario:?} seed {seed}");

            // Re-run every candidate independently of the tuner.
            let mut any_feasible = false;
            let mut selected: Option<BenchReport> = None;
            for &batch in &spec.batch_candidates {
                for &policy in &spec.policy_candidates {
                    let r = candidate_report(&regs, &spec, batch, policy);
                    any_feasible |= feasible(&spec, &r);
                    // No candidate may beat the tuned throughput within
                    // the same feasibility class.
                    if feasible(&spec, &r) == tuned.feasible {
                        assert!(
                            tuned.throughput_rps >= r.throughput_rps,
                            "{tag}: candidate batch {batch} {policy:?} at {} rps beats the \
                             tuned {} rps",
                            r.throughput_rps,
                            tuned.throughput_rps
                        );
                    }
                    if batch == tuned.batch && policy.name() == tuned.policy {
                        selected = Some(r);
                    }
                }
            }
            // Never SLO-infeasible when a feasible point exists.
            assert_eq!(
                tuned.feasible, any_feasible,
                "{tag}: tuner feasibility {} but a feasible candidate {}",
                tuned.feasible,
                if any_feasible { "exists" } else { "does not exist" }
            );
            // The reported numbers are exactly the selected candidate's.
            let sel = selected.unwrap_or_else(|| panic!("{tag}: selection not in the grid"));
            assert_eq!(feasible(&spec, &sel), tuned.feasible, "{tag}");
            assert_eq!(sel.throughput_rps, tuned.throughput_rps, "{tag}");
            assert_eq!(sel.goodput_rps, tuned.goodput_rps, "{tag}");

            // Never worse than the untuned default (smallest batch, FIFO)
            // when both land in the same feasibility class.
            let default =
                candidate_report(&regs, &spec, spec.batch_candidates[0], SchedulePolicy::Fifo);
            if feasible(&spec, &default) == tuned.feasible {
                assert!(
                    tuned.throughput_rps >= default.throughput_rps,
                    "{tag}: tuned {} rps below the untuned default {} rps",
                    tuned.throughput_rps,
                    default.throughput_rps
                );
            }

            // The derived overload posture is structurally sound:
            // admission budgets are 2x the chosen batch for every model...
            assert_eq!(tuned.admission.len(), spec.models.len(), "{tag}");
            for model in &spec.models {
                assert_eq!(tuned.admission[model], 2 * tuned.batch as usize, "{tag}: {model}");
            }
            // ...the expected mix accounts for the whole trace...
            assert_eq!(tuned.expected_mix.values().sum::<u64>(), spec.requests, "{tag}");
            // ...and priority tiers are the popularity ranking (tier 0 =
            // most offered, ties by name).
            let mut ranked: Vec<(&String, u64)> =
                tuned.expected_mix.iter().map(|(k, &v)| (k, v)).collect();
            ranked.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(b.0)));
            for (tier, (name, _)) in ranked.iter().enumerate() {
                assert_eq!(tuned.priorities[*name], tier as u8, "{tag}: {name}");
            }
        }
    }
}

#[test]
fn overload_accounting_closes_across_seeds_and_scenarios() {
    let regs = prop_registries();
    let factory = |batch: u32| -> flex_tpu::error::Result<Arc<ModelRegistry>> {
        Ok(Arc::clone(&regs[&batch]))
    };
    for scenario in Scenario::ALL {
        for seed in [3u64, 7, 11, 15, 19, 23, 27] {
            let avg = probe_avg_service_us(&regs, scenario, seed);
            let spec = tight_spec(scenario, seed, avg);
            let tuned = bench::tune::tune(&factory, &spec).unwrap();
            let (controlled, plain) =
                bench::overload_comparison(&regs[&tuned.batch], &spec, &tuned).unwrap();
            for r in [&controlled, &plain] {
                let tag = format!("{scenario:?} seed {seed} {}", r.policy);
                assert_eq!(
                    r.served + r.dropped_deadline + r.rejected + r.shed,
                    r.offered,
                    "{tag}: aggregate accounting leaks requests"
                );
                assert_eq!(r.admitted, r.offered - r.rejected, "{tag}");
                assert!(r.slo_met <= r.served, "{tag}");
                assert_eq!(
                    r.miss_by_tier.values().sum::<u64>(),
                    r.dropped_deadline + r.shed,
                    "{tag}: tier attribution loses misses"
                );
                let mut offered = 0u64;
                for (model, m) in &r.per_model {
                    assert_eq!(
                        m.served + m.dropped_deadline + m.rejected + m.shed,
                        m.offered,
                        "{tag}: {model} accounting leaks requests"
                    );
                    assert!(m.slo_met <= m.served, "{tag}: {model}");
                    offered += m.offered;
                }
                assert_eq!(offered, r.offered, "{tag}: per-model offered totals");
                assert_eq!(
                    r.per_model.values().map(|m| m.served).sum::<u64>(),
                    r.served,
                    "{tag}: per-model served totals"
                );
                assert_eq!(
                    r.per_model.values().map(|m| m.rejected).sum::<u64>(),
                    r.rejected,
                    "{tag}: per-model rejected totals"
                );
                assert_eq!(
                    r.per_model.values().map(|m| m.shed).sum::<u64>(),
                    r.shed,
                    "{tag}: per-model shed totals"
                );
            }
            // Plain deadline-edf runs without door or shedding controls.
            assert_eq!(plain.rejected, 0, "{scenario:?} seed {seed}");
            assert_eq!(plain.shed, 0, "{scenario:?} seed {seed}");
        }
    }
}

#[test]
fn degraded_mode_sheds_strictly_by_priority_order_across_seeds() {
    const MODELS: [&str; 3] = ["m0", "m1", "m2"];
    let forecast = ReconfigForecast {
        first: Some(Dataflow::Os),
        last: Some(Dataflow::Os),
        internal_switches: 0,
    };
    for seed in 0..12u64 {
        let mut s: Scheduler<u64> = Scheduler::new(SchedulePolicy::DeadlineEdf);
        s.set_overload_control(true);
        for (tier, name) in MODELS.iter().enumerate() {
            s.set_profile(ModelProfile {
                model: name.to_string(),
                batch: 2,
                forecast,
                priority: tier as u8,
            });
        }
        // Sustained deadline pressure: every pop sweeps freshly expired
        // requests until degraded mode engages, then a few more rounds to
        // saturate the pressure accumulator.
        let mut swept = Vec::new();
        let mut id = 1_000_000u64;
        while !s.degraded() {
            s.push("m0", 0, Some(1), id);
            id += 1;
            let _ = s.pop(10, true, &mut swept);
        }
        for _ in 0..6 {
            s.push("m0", 0, Some(1), id);
            id += 1;
            let _ = s.pop(10, true, &mut swept);
        }
        assert!(s.degraded(), "seed {seed}");

        // Seed-varied live queue depths, 3..=6 per model (total > the
        // degraded capacity of 6, so shedding must trigger).
        let mut x = seed
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let mut counts = [0usize; 3];
        for c in &mut counts {
            x = x
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            *c = 3 + ((x >> 33) % 4) as usize;
        }
        for (m, &count) in counts.iter().enumerate() {
            for k in 0..count {
                s.push(MODELS[m], 10, Some(1_000_000), (m as u64 + 1) * 10_000 + k as u64);
            }
        }
        let total: usize = counts.iter().sum();

        let mut expired = Vec::new();
        let batch = s.pop(11, true, &mut expired).expect("live requests launch");
        assert!(expired.is_empty(), "seed {seed}: nothing was expired");
        let mut shed: Vec<(String, u64)> = Vec::new();
        s.drain_shed(&mut shed);
        // Depth beyond twice the degraded capacity (3 models x 2x1) shed.
        assert_eq!(shed.len(), total - 6, "seed {seed}: counts {counts:?}");
        let tier = |model: &str| MODELS.iter().position(|m| *m == model).unwrap();
        // Strictly lowest-priority (largest tier) first.
        for w in shed.windows(2) {
            assert!(
                tier(&w[0].0) >= tier(&w[1].0),
                "seed {seed}: shed order violates priority: {shed:?}"
            );
        }
        // A shed at tier t means every lower-priority queue was already
        // drained empty.
        let min_shed = shed.iter().map(|(m, _)| tier(m)).min().unwrap();
        for (t, name) in MODELS.iter().enumerate() {
            if t > min_shed {
                assert_eq!(
                    s.pending_for(name),
                    0,
                    "seed {seed}: tier {t} kept requests while tier {min_shed} shed"
                );
            }
        }
        // Newest-first within each victim model: per-model ids descend.
        for name in MODELS {
            let ids: Vec<u64> = shed
                .iter()
                .filter(|(m, _)| m == name)
                .map(|&(_, id)| id)
                .collect();
            for w in ids.windows(2) {
                assert!(w[0] > w[1], "seed {seed}: {name} shed oldest first: {ids:?}");
            }
        }
        // The launch itself came from a live queue, not the shed log.
        assert!(!batch.items.is_empty(), "seed {seed}");
    }
}

#[test]
fn tuned_config_warm_starts_and_retunes_on_drift() {
    let dir = tmpdir("warm");
    let store = PlanStore::open(&dir).unwrap();
    let regs = prop_registries();
    let factory = |batch: u32| -> flex_tpu::error::Result<Arc<ModelRegistry>> {
        Ok(Arc::clone(&regs[&batch]))
    };
    let mut spec = TuneSpec::new(prop_models());
    spec.seed = 40;
    spec.requests = 240;
    spec.mean_interarrival_us = 500;
    spec.deadline_us = None;
    spec.batch_candidates = vec![1, 2];
    spec.policy_candidates = vec![SchedulePolicy::Fifo, SchedulePolicy::DeadlineEdf];
    let reference = &regs[&1];

    let cold = bench::tune_or_load(Some(&store), reference, &factory, &spec).unwrap();
    assert_eq!(cold.source, DocSource::Computed);
    assert_eq!(cold.sweeps, 4, "2 batches x 2 policies");

    // Same spec, same store: warm start with zero sweep re-simulation.
    let warm = bench::tune_or_load(Some(&store), reference, &factory, &spec).unwrap();
    assert_eq!(warm.source, DocSource::Loaded);
    assert_eq!(warm.sweeps, 0);
    assert_eq!(warm.tuned, cold.tuned);

    // Statistically equivalent traffic (a reseeded trace of the same
    // shape) stays inside the drift budget and still warm-starts.
    let mut reseeded = spec.clone();
    reseeded.seed = 41;
    assert_eq!(reseeded.config_string(), spec.config_string());
    assert!(
        bench::mix_drift_millis(&cold.tuned.expected_mix, &reseeded.trace_mix())
            < bench::DRIFT_RETUNE_MILLIS,
        "reseeded uniform mix drifted past the re-tune threshold"
    );
    let still_warm = bench::tune_or_load(Some(&store), reference, &factory, &reseeded).unwrap();
    assert_eq!(still_warm.source, DocSource::Loaded);
    assert_eq!(still_warm.tuned, cold.tuned);

    // A drifted mix — skewed traffic under the identical config string —
    // refuses the warm start and re-tunes.
    let mut drifted = spec.clone();
    drifted.scenario = Scenario::Skewed;
    assert_eq!(drifted.config_string(), spec.config_string());
    assert!(
        bench::mix_drift_millis(&cold.tuned.expected_mix, &drifted.trace_mix())
            >= bench::DRIFT_RETUNE_MILLIS,
        "skewed mix must read as drifted"
    );
    let retuned = bench::tune_or_load(Some(&store), reference, &factory, &drifted).unwrap();
    assert_eq!(retuned.source, DocSource::Computed);
    assert_eq!(retuned.sweeps, 4);
    assert_eq!(retuned.tuned.expected_mix, drifted.trace_mix());

    // The persisted record now reflects the re-tune, keyed by the
    // registry's tuned provenance.
    let stored = TunedConfig::load(&store, &reference.tuned_provenance()).unwrap();
    assert_eq!(stored, retuned.tuned);

    // Without a store every call is a cold sweep.
    let stateless = bench::tune_or_load(None, reference, &factory, &spec).unwrap();
    assert_eq!(stateless.source, DocSource::Computed);
    assert_eq!(stateless.sweeps, 4);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gated_tune_beats_plain_edf_and_matches_committed_baseline() {
    let spec = TuneSpec::new(GATED_MODELS.iter().map(|s| s.to_string()).collect());
    let run_gated = || {
        let factory = |batch: u32| -> flex_tpu::error::Result<Arc<ModelRegistry>> {
            Ok(registry(GATED_SIZE, batch, &GATED_MODELS))
        };
        let tuned = bench::tune::tune(&factory, &spec).unwrap();
        let serving = registry(GATED_SIZE, tuned.batch, &GATED_MODELS);
        let (controlled, plain) = bench::overload_comparison(&serving, &spec, &tuned).unwrap();
        TuneDoc { tuned, controlled, plain }
    };
    let doc = run_gated();

    // The oracle (the tentpole's acceptance criterion): the tuned
    // overload posture — admission budgets + priority tiers + degraded
    // mode on deadline-edf — sustains strictly more SLO-met goodput than
    // plain deadline-edf on the same overloaded trace.
    assert!(
        doc.controlled.goodput_rps > doc.plain.goodput_rps,
        "overload control goodput {:.1} rps does not beat plain deadline-edf {:.1} rps",
        doc.controlled.goodput_rps,
        doc.plain.goodput_rps
    );
    for r in [&doc.controlled, &doc.plain] {
        assert_eq!(
            r.served + r.dropped_deadline + r.rejected + r.shed,
            r.offered,
            "{}: accounting leaks requests",
            r.policy
        );
    }
    // Admission control genuinely engaged (the gated trace overloads the
    // registry) and nothing it admitted was wasted on the controlled run.
    assert!(doc.controlled.rejected > 0, "gated trace must trip admission control");

    // Byte reproducibility through fresh registries: what CI `cmp`s.
    let again = run_gated();
    assert_eq!(doc.to_json().to_string(), again.to_json().to_string());

    // Golden gate, through the same `gate_tune` the CI perf job runs.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/tune_baseline.json");
    if std::env::var_os("FLEX_TPU_UPDATE_GOLDEN").is_some() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, format!("{}\n", doc.to_json())).unwrap();
        return;
    }
    let committed = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "missing {}: {e}\n(generate it with FLEX_TPU_UPDATE_GOLDEN=1 cargo test --test tune)",
            path.display()
        )
    });
    let baseline = TuneDoc::from_json(&parse(&committed).unwrap()).unwrap();
    match bench::gate_tune(&doc, &baseline) {
        Ok(checks) => assert!(!checks.is_empty()),
        Err(e) => panic!(
            "tune gate failed: {e}\n(bless intentional model changes with \
             FLEX_TPU_UPDATE_GOLDEN=1 cargo test --test tune and commit the diff)"
        ),
    }
}
