//! Acceptance tests for energy-aware plan objectives:
//!
//! 1. **latency default is byte-identical** — `compile_plan` (the
//!    historical entry point) and an explicit `PlanObjective::Latency`
//!    compile produce equal plans across the whole zoo, so the objective
//!    axis cannot perturb any existing golden;
//! 2. **energy dominance** — the pure-energy objective never compiles a
//!    plan with more total energy than the latency plan over the same
//!    candidate grids, layer by layer and in total, and strictly improves
//!    on at least one zoo model at 8x8 (the divergence that makes the
//!    objective worth having);
//! 3. **EDP sits between** — per layer, the EDP choice's cycles x energy
//!    product is never above either single-axis plan's product;
//! 4. **provenance isolation** — the objective is part of every
//!    deployment's provenance: re-opening a store under the same
//!    objective warm-starts (zero simulate calls), a different objective
//!    reads cold instead of reusing the wrong plan.

use std::path::PathBuf;
use std::sync::Arc;

use flex_tpu::config::ArchConfig;
use flex_tpu::coordinator::plan::{compile_plan, compile_plan_objective, PlanObjective};
use flex_tpu::inference::{ModelRegistry, PlacementPolicy, PlanSource, SimBackend};
use flex_tpu::sim::engine::SimOptions;
use flex_tpu::sim::parallel::ShapeCache;
use flex_tpu::sim::PlanStore;
use flex_tpu::topology::zoo;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("flex-tpu-objective-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn latency_objective_is_byte_identical_across_the_zoo() {
    let opts = SimOptions::default();
    for size in [8u32, 32] {
        let arch = ArchConfig::square(size);
        for topo in zoo::all_models() {
            let cache = ShapeCache::new();
            let legacy = compile_plan(&arch, &topo, opts, 1, &cache);
            let explicit =
                compile_plan_objective(&arch, &topo, opts, 1, PlanObjective::Latency, &cache);
            assert_eq!(
                legacy, explicit,
                "{} at {size}x{size}: latency objective must reproduce the default",
                topo.name
            );
            assert_eq!(legacy.objective, PlanObjective::Latency);
        }
    }
}

#[test]
fn energy_objective_never_costs_more_energy_and_wins_somewhere() {
    let arch = ArchConfig::square(8);
    let opts = SimOptions::default();
    let mut strictly_better = Vec::new();
    for topo in zoo::all_models() {
        let cache = ShapeCache::new();
        let lat = compile_plan_objective(&arch, &topo, opts, 1, PlanObjective::Latency, &cache);
        let en = compile_plan_objective(&arch, &topo, opts, 1, PlanObjective::Energy, &cache);
        for (ll, le) in lat.layers.iter().zip(en.layers.iter()) {
            assert!(
                le.chosen_energy_pj() <= ll.chosen_energy_pj(),
                "{} layer {}: energy objective chose {} pJ over latency's {} pJ",
                topo.name,
                le.name,
                le.chosen_energy_pj(),
                ll.chosen_energy_pj()
            );
        }
        assert!(en.flex_energy_pj() <= lat.flex_energy_pj(), "{}", topo.name);
        if en.flex_energy_pj() < lat.flex_energy_pj() {
            strictly_better.push(topo.name.clone());
        }
    }
    assert!(
        !strictly_better.is_empty(),
        "pure-energy must strictly reduce total energy on at least one zoo model at 8x8"
    );
}

#[test]
fn edp_objective_minimizes_the_per_layer_product() {
    let arch = ArchConfig::square(8);
    let opts = SimOptions::default();
    for topo in zoo::all_models() {
        let cache = ShapeCache::new();
        let lat = compile_plan_objective(&arch, &topo, opts, 1, PlanObjective::Latency, &cache);
        let en = compile_plan_objective(&arch, &topo, opts, 1, PlanObjective::Energy, &cache);
        let edp = compile_plan_objective(&arch, &topo, opts, 1, PlanObjective::Edp, &cache);
        let product =
            |l: &flex_tpu::coordinator::plan::PlanLayer| -> u128 {
                u128::from(l.layer_cycles()) * u128::from(l.chosen_energy_pj())
            };
        for ((ll, le), lp) in lat.layers.iter().zip(en.layers.iter()).zip(edp.layers.iter()) {
            assert!(
                product(lp) <= product(ll) && product(lp) <= product(le),
                "{} layer {}: EDP product above a single-axis plan's",
                topo.name,
                lp.name
            );
        }
    }
}

#[test]
fn objective_is_part_of_store_provenance() {
    let dir = tmpdir("provenance");
    let arch = ArchConfig::square(8);
    let backend = || Arc::new(SimBackend::from_zoo("alexnet", 2).unwrap());
    let open = |objective: PlanObjective| {
        ModelRegistry::with_placement_objective(
            arch,
            Some(PlanStore::open(&dir).unwrap()),
            PlacementPolicy::Single,
            objective,
        )
        .unwrap()
    };
    // Cold: the energy plan compiles and persists under its own key.
    let cold = open(PlanObjective::Energy).register(backend()).unwrap();
    assert_eq!(cold.plan_source, PlanSource::Compiled);
    // Same objective re-opens warm: plan loaded, zero simulate calls.
    let warm_registry = open(PlanObjective::Energy);
    let warm = warm_registry.register(backend()).unwrap();
    assert_eq!(warm.plan_source, PlanSource::Loaded);
    assert_eq!(warm.provenance, cold.provenance);
    assert!(warm.shapes_preloaded > 0);
    let stats = warm_registry.cache_stats();
    assert_eq!(stats.misses, 0, "warm same-objective start must not simulate: {stats:?}");
    assert_eq!(stats.hit_rate(), 1.0);
    // A different objective must not pick up the energy plan.
    let cross = open(PlanObjective::Latency).register(backend()).unwrap();
    assert_eq!(
        cross.plan_source,
        PlanSource::Compiled,
        "cross-objective registration reused a plan compiled under another objective"
    );
    assert_ne!(cross.provenance, cold.provenance);
    let _ = std::fs::remove_dir_all(&dir);
}
