//! Property tests: the functional PE-level array (paper Fig. 3/4) vs the
//! GEMM oracle and the analytical cycle model.
//!
//! These are the two load-bearing invariants of the whole reproduction:
//!
//! 1. every dataflow configuration computes the exact GEMM (reconfiguration
//!    changes scheduling, never math);
//! 2. the measured cycle count equals the closed-form fold plan, for every
//!    random shape — i.e. the ScaleSim-equivalent is telling the truth
//!    about the microarchitecture.
//!
//! proptest is unavailable offline; `flex_tpu::util::rng::property` gives
//! seeded, replayable randomized sweeps instead.

use flex_tpu::arch::{FlexArray, Mat};
use flex_tpu::config::ArchConfig;
use flex_tpu::coordinator::cmu::Cmu;
use flex_tpu::coordinator::MainController;
use flex_tpu::sim::{dataflow, Dataflow, Gemm};
use flex_tpu::util::rng::{property, Rng};

fn random_case(rng: &mut Rng) -> (usize, usize, usize, usize, usize) {
    let r = rng.range(1, 6);
    let c = rng.range(1, 6);
    let m = rng.range(1, 20);
    let k = rng.range(1, 20);
    let n = rng.range(1, 20);
    (r, c, m, k, n)
}

#[test]
fn prop_all_dataflows_compute_exact_gemm() {
    property("exact-gemm", 0xA11, 60, |rng| {
        let (r, c, m, k, n) = random_case(rng);
        let a = Mat::random_i8(m, k, rng.next_u64());
        let b = Mat::random_i8(k, n, rng.next_u64());
        let want = a.matmul(&b);
        for df in Dataflow::ALL {
            let mut arr = FlexArray::new(r, c);
            arr.configure(df);
            let run = arr.run_gemm(&a, &b);
            assert_eq!(run.out, want, "{df} on {r}x{c}, GEMM {m}x{k}x{n}");
        }
    });
}

#[test]
fn prop_functional_cycles_equal_analytical() {
    property("cycles-equal", 0xC1C, 60, |rng| {
        let (r, c, m, k, n) = random_case(rng);
        let arch = ArchConfig {
            array_rows: r as u32,
            array_cols: c as u32,
            ..ArchConfig::square(1)
        };
        let a = Mat::random_i8(m, k, rng.next_u64());
        let b = Mat::random_i8(k, n, rng.next_u64());
        for df in Dataflow::ALL {
            let plan = dataflow::plan(&Gemm::new(m as u64, k as u64, n as u64), &arch, df);
            let mut arr = FlexArray::new(r, c);
            arr.configure(df);
            let run = arr.run_gemm(&a, &b);
            assert_eq!(
                run.cycles,
                plan.compute_cycles(),
                "{df} on {r}x{c}, GEMM {m}x{k}x{n}"
            );
            assert_eq!(run.folds, plan.folds(), "{df} folds");
        }
    });
}

#[test]
fn prop_reconfiguration_sequences_preserve_math() {
    // Arbitrary reconfiguration sequences through the CMU/controller path:
    // a multi-"layer" run where every layer flips dataflow must still be
    // bit-exact per layer.
    property("reconfig-sequences", 0x5EC, 20, |rng| {
        let layers = rng.range(2, 5);
        let r = rng.range(2, 4);
        let table: Vec<Dataflow> = (0..layers)
            .map(|_| *rng.pick(&Dataflow::ALL))
            .collect();
        let inputs: Vec<(Mat, Mat)> = (0..layers)
            .map(|_| {
                let m = rng.range(1, 8);
                let k = rng.range(1, 8);
                let n = rng.range(1, 8);
                (
                    Mat::random_i8(m, k, rng.next_u64()),
                    Mat::random_i8(k, n, rng.next_u64()),
                )
            })
            .collect();
        let arch = ArchConfig::square(r as u32);
        let cmu = Cmu::program("prop", table).unwrap();
        let mc = MainController::new(arch, cmu);
        let run = mc.run_functional(&inputs).unwrap();
        for (i, (a, b)) in inputs.iter().enumerate() {
            assert_eq!(run.outputs[i], a.matmul(b), "layer {i}");
        }
    });
}

#[test]
fn cycle_formulas_follow_stream_lengths() {
    // Directional sanity: OS cost grows with K only (per fold), WS with M,
    // IS with N — the asymmetry the per-layer selection exploits.
    let arch = ArchConfig::square(8);
    let base = Gemm::new(8, 8, 8);
    let big_k = Gemm::new(8, 800, 8);
    let big_m = Gemm::new(800, 8, 8);
    let big_n = Gemm::new(8, 8, 800);

    let cycles = |g: &Gemm, df| dataflow::plan(g, &arch, df).compute_cycles();

    // K stresses OS (streamed) but folds WS/IS.
    assert_eq!(
        cycles(&big_k, Dataflow::Os),
        cycles(&base, Dataflow::Os) + 792
    );
    // M stresses WS (streamed) but folds OS / IS.
    assert_eq!(
        cycles(&big_m, Dataflow::Ws),
        cycles(&base, Dataflow::Ws) + 792
    );
    // N stresses IS (streamed) but folds OS / WS.
    assert_eq!(
        cycles(&big_n, Dataflow::Is),
        cycles(&base, Dataflow::Is) + 792
    );
}
