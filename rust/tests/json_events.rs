//! Adversarial agreement tests for the two JSON read paths (ISSUE 8).
//!
//! `util::json` now has a tree parser and a streaming event parser built
//! on the same grammar machinery.  Agreement is enforced here from the
//! *outside*: an independent recursive fold over [`EventParser`] (written
//! in this test, not the library) rebuilds a `Value` and must match
//! [`parse`] exactly — same value or same rejection — on deep nesting up
//! to and past the depth cap, truncated documents, corrupted bytes,
//! surrogate/escape pathologies, and numbers at the u64/f64 boundaries.

use flex_tpu::util::json::{parse, parse_events, EventParser, JsonEvent, Value, MAX_DEPTH};
use flex_tpu::util::rng::{property, Rng};

/// Rebuild a `Value` by folding the event stream — deliberately an
/// independent consumer, so a bug in the library's own event-driven
/// `parse` fold can't hide itself.
fn value_via_events(text: &str) -> Result<Value, String> {
    let mut p = EventParser::new(text);
    let ev = first(&mut p)?;
    let v = build(&mut p, ev)?;
    p.finish().map_err(|e| e.to_string())?;
    Ok(v)
}

fn first<'a>(p: &mut EventParser<'a>) -> Result<JsonEvent<'a>, String> {
    p.next_event()
        .map_err(|e| e.to_string())?
        .ok_or_else(|| "no value".to_string())
}

fn build<'a>(p: &mut EventParser<'a>, ev: JsonEvent<'a>) -> Result<Value, String> {
    Ok(match ev {
        JsonEvent::Null => Value::Null,
        JsonEvent::Bool(b) => Value::Bool(b),
        JsonEvent::Num(n) => Value::Num(n),
        JsonEvent::Str(s) => Value::Str(s.into_owned()),
        JsonEvent::ArrStart => {
            let mut items = Vec::new();
            loop {
                match first(p)? {
                    JsonEvent::ArrEnd => break,
                    ev => items.push(build(p, ev)?),
                }
            }
            Value::Arr(items)
        }
        JsonEvent::ObjStart => {
            let mut fields = Vec::new();
            loop {
                match first(p)? {
                    JsonEvent::ObjEnd => break,
                    JsonEvent::Key(k) => {
                        let key = k.into_owned();
                        let ev = first(p)?;
                        fields.push((key, build(p, ev)?));
                    }
                    other => return Err(format!("unexpected {other:?}")),
                }
            }
            Value::Obj(fields)
        }
        other => return Err(format!("unexpected {other:?}")),
    })
}

/// Both paths on one input: same parsed value, or both rejecting.  Also
/// checks the `parse_events` visitor wrapper accepts/rejects in lockstep.
fn agree(text: &str) -> Option<Value> {
    let tree = parse(text).ok();
    let via_events = value_via_events(text).ok();
    assert_eq!(tree, via_events, "paths disagree on {text:?}");
    assert_eq!(
        tree.is_some(),
        parse_events(text, |_| Ok(())).is_ok(),
        "visitor wrapper disagrees on {text:?}"
    );
    tree
}

#[test]
fn depth_cap_splits_accept_from_reject_identically() {
    for depth in [1usize, 64, MAX_DEPTH - 1, MAX_DEPTH, MAX_DEPTH + 1, 200, 2000] {
        let arrays = format!("{}0{}", "[".repeat(depth), "]".repeat(depth));
        let got = agree(&arrays);
        assert_eq!(got.is_some(), depth <= MAX_DEPTH, "arrays at depth {depth}");
        let objects = format!("{}0{}", "{\"k\":".repeat(depth), "}".repeat(depth));
        let got = agree(&objects);
        assert_eq!(got.is_some(), depth <= MAX_DEPTH, "objects at depth {depth}");
        // Unclosed deep prefixes reject (cap or truncation) on both paths.
        assert!(agree(&"[".repeat(depth)).is_none());
    }
}

#[test]
fn surrogate_and_escape_pathologies_agree() {
    // (input, expected decoded string or None for rejection)
    let cases: &[(&str, Option<&str>)] = &[
        (r#""\ud83d\ude00""#, Some("\u{1F600}")), // valid surrogate pair
        (r#""\ud800""#, None),                    // lone high surrogate
        (r#""\ud800x""#, None),                   // high followed by raw char
        (r#""\ud800\ud800""#, None),              // high followed by high
        (r#""\udc00""#, None),                    // lone low surrogate
        (r#""\udfff\udfff""#, None),              // low-low pair
        (r#""\u0041\u00e9""#, Some("Aé")),        // BMP escapes
        (r#""\uffff""#, Some("\u{FFFF}")),        // BMP ceiling
        (r#""\q""#, None),                        // unknown escape
        (r#""\u00""#, None),                      // truncated \u
        (r#""\u00zz""#, None),                    // non-hex \u
        (r#""\""#, None),                         // escape then EOF
        ("\"unterminated", None),
        (r#""mixed \n raw	tab""#, Some("mixed \n raw\ttab")),
    ];
    for (text, want) in cases {
        let got = agree(text);
        match want {
            Some(s) => assert_eq!(
                got.as_ref().and_then(|v| v.as_str()),
                Some(*s),
                "{text:?}"
            ),
            None => assert!(got.is_none(), "{text:?} must reject"),
        }
    }
}

#[test]
fn boundary_numbers_agree_bitwise() {
    let texts = [
        "0",
        "-0",
        "9007199254740992",     // 2^53
        "9007199254740993",     // 2^53 + 1 (rounds; both must round alike)
        "18446744073709551615", // u64::MAX
        "1.7976931348623157e308",
        "5e-324",               // smallest subnormal
        "2.2250738585072014e-308",
        "1e999",                // overflows to +inf on both paths
        "-1e999",
        "0.1",
        "1.",                   // quirk: f64::from_str accepts it; keep both doing so
        "007",                  // quirk: leading zeros accepted; keep both doing so
    ];
    for text in texts {
        let tree = parse(text);
        let mut p = EventParser::new(text);
        match (tree, p.next_event()) {
            (Ok(Value::Num(a)), Ok(Some(JsonEvent::Num(b)))) => {
                assert_eq!(a.to_bits(), b.to_bits(), "{text}");
            }
            (tree, ev) => panic!("{text}: tree {tree:?} events {ev:?}"),
        }
    }
    for text in ["-", "+1", "1e", "1e+", ".5", "--1", "1..2"] {
        assert!(agree(text).is_none(), "{text:?} must reject");
    }
}

#[test]
fn malformed_structures_agree() {
    let corpus = [
        "",
        " \t\n",
        "[",
        "]",
        "{",
        "}",
        "{\"a\"",
        "{\"a\":",
        "{\"a\":}",
        "{\"a\":1,}",
        "{1: 2}",
        "[1 2]",
        "{\"a\" 1}",
        "[1,]",
        "[,1]",
        "nul",
        "truex",
        "falsey",
        "null null",
        "[] []",
        "[]{}",
        "[]",
        "{}",
        "[[]]",
        "{\"a\": {}}",
        " 7 ",
        "\t\nnull\r ",
    ];
    for text in corpus {
        agree(text);
    }
}

fn gen_value(rng: &mut Rng, depth: usize) -> Value {
    const STRINGS: &[&str] = &[
        "",
        "plain",
        "esc \"q\" \\b\\",
        "nl\nand\ttab",
        "ünïcodé \u{1F600}",
        "ctrl \u{0001}\u{001f}",
    ];
    let pick = if depth >= 3 { rng.range(0, 3) } else { rng.range(0, 5) };
    match pick {
        0 => Value::Null,
        1 => Value::Bool(rng.range(0, 1) == 1),
        2 => Value::Num(match rng.range(0, 2) {
            0 => rng.range_u64(0, 5000) as f64 - 2500.0,
            1 => rng.next_u64() as f64,
            _ => rng.f64() * 1e9,
        }),
        3 => Value::Str((*rng.pick(STRINGS)).to_string()),
        4 => {
            let n = rng.range(0, 4);
            Value::Arr((0..n).map(|_| gen_value(rng, depth + 1)).collect())
        }
        _ => {
            let n = rng.range(0, 4);
            Value::Obj(
                (0..n)
                    .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                    .collect(),
            )
        }
    }
}

#[test]
fn random_documents_truncations_and_corruptions_agree() {
    property("json event/tree agreement", 0xE_4E47, 120, |rng| {
        let value = gen_value(rng, 0);
        let text = value.to_string();
        let parsed = agree(&text).expect("emitted JSON must parse on both paths");
        assert_eq!(parsed, value);
        // Every char-boundary truncation agrees (almost all reject; a
        // prefix like "12" of "123" legitimately parses on both).
        for cut in 0..text.len() {
            if text.is_char_boundary(cut) {
                agree(&text[..cut]);
            }
        }
        // Single-byte corruption with a structural character agrees.
        let mut bytes = text.clone().into_bytes();
        let i = rng.range(0, bytes.len() - 1);
        if bytes[i].is_ascii() {
            bytes[i] = *rng.pick(b"{}[]:,\"\\x09 ".as_slice());
            if let Ok(corrupted) = String::from_utf8(bytes) {
                agree(&corrupted);
            }
        }
    });
}
