//! Round-trip property test for the in-tree JSON substrate (ISSUE 3):
//! plan/shape persistence depends on `util::json`, so emitted documents
//! must be a fixed point of `parse` — **serialize → parse → serialize is
//! byte-identical** for arbitrarily nested objects/arrays, strings full of
//! escape sequences, and numbers spanning the full u64 range.
//!
//! (The first serialization canonicalizes: numbers take their shortest
//! round-trip form and key order is preserved.  From then on the text and
//! the value must be mutual fixed points.)

use flex_tpu::util::json::{parse, Value};
use flex_tpu::util::rng::{property, Rng};

/// Strings that exercise every escape path: quotes, backslashes, control
/// characters, multi-byte UTF-8 and astral-plane codepoints.
const STRING_POOL: &[&str] = &[
    "",
    "plain",
    "with \"quotes\" and \\backslashes\\",
    "line\nbreaks\tand\rreturns",
    "control \u{0001}\u{001f} chars",
    "unicode: héllo wörld",
    "astral: \u{1F600}\u{10FFFF}",
    "slash / and null-ish \u{0000}x",
];

fn gen_number(rng: &mut Rng) -> f64 {
    match rng.range(0, 4) {
        // Small signed integers (the common cycle-count shape).
        0 => rng.range_u64(0, 2000) as f64 - 1000.0,
        // Full-range u64s, including values far above 2^53 that must
        // round-trip through the emitted shortest f64 form.
        1 => rng.next_u64() as f64,
        // Fractions.
        2 => rng.f64() * 1000.0,
        // Large magnitudes with exponents.
        3 => rng.f64() * 1e300,
        // Negative fractions.
        _ => -rng.f64() * 1e9,
    }
}

fn gen_value(rng: &mut Rng, depth: usize) -> Value {
    let pick = if depth >= 3 { rng.range(0, 3) } else { rng.range(0, 5) };
    match pick {
        0 => Value::Null,
        1 => Value::Bool(rng.range(0, 1) == 1),
        2 => Value::Num(gen_number(rng)),
        3 => Value::Str((*rng.pick(STRING_POOL)).to_string()),
        4 => {
            let n = rng.range(0, 4);
            Value::Arr((0..n).map(|_| gen_value(rng, depth + 1)).collect())
        }
        _ => {
            let n = rng.range(0, 4);
            Value::Obj(
                (0..n)
                    .map(|i| {
                        let key = format!("k{}_{}", i, rng.pick(STRING_POOL));
                        (key, gen_value(rng, depth + 1))
                    })
                    .collect(),
            )
        }
    }
}

#[test]
fn serialize_parse_serialize_is_byte_identical() {
    property("json round trip", 0x15_5E3, 300, |rng| {
        let value = gen_value(rng, 0);
        let first = value.to_string();
        let parsed = match parse(&first) {
            Ok(v) => v,
            Err(e) => panic!("emitted JSON must parse: {e}\n{first}"),
        };
        let second = parsed.to_string();
        assert_eq!(first, second, "second serialization diverged");
        // And the parsed value is itself a fixed point.
        assert_eq!(parse(&second).unwrap(), parsed);
    });
}

#[test]
fn large_u64s_survive_the_emitted_form() {
    // Values beyond 2^53 lose integer precision when they become f64s, but
    // the *emitted text* must still round-trip exactly: parse(to_string(x))
    // == x for every representable f64.
    let mut rng = Rng::new(0xB16_B00);
    for _ in 0..2000 {
        let n = rng.next_u64();
        let v = Value::Num(n as f64);
        let text = v.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back, v, "u64 {n} → {text}");
        assert_eq!(back.to_string(), text);
    }
    // The exact 2^53 boundary and its neighbours.
    for n in [(1u64 << 53) - 1, 1u64 << 53, (1u64 << 53) + 2, u64::MAX] {
        let v = Value::Num(n as f64);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap().to_string(), text, "u64 {n}");
    }
}

#[test]
fn finite_f64_values_round_trip_exactly() {
    // The report store (`report-dse` documents) persists energy floats;
    // the contract is *value* exactness: parse(to_string(x)) returns the
    // same f64 bits for every finite nonzero double (the writer emits
    // Rust's shortest round-trip decimal form).  Negative zero is the one
    // deliberate exception — it canonicalizes to integer 0.
    let mut rng = Rng::new(0xD5E_F10);
    let mut checked = 0u32;
    for _ in 0..4000 {
        let x = f64::from_bits(rng.next_u64());
        if !x.is_finite() || x == 0.0 {
            continue;
        }
        checked += 1;
        let text = Value::Num(x).to_string();
        let y = parse(&text).unwrap().as_f64().unwrap();
        assert_eq!(y.to_bits(), x.to_bits(), "{x:e} -> {text} -> {y:e}");
    }
    assert!(checked > 3000, "random f64s were mostly finite: {checked}");
    for x in [
        0.1,
        1.0 / 3.0,
        6.63e-1,
        f64::MIN_POSITIVE,
        f64::MAX,
        -1.5e-300,
    ] {
        let y = parse(&Value::Num(x).to_string()).unwrap().as_f64().unwrap();
        assert_eq!(y.to_bits(), x.to_bits(), "{x:e}");
    }
}

#[test]
fn escape_sequences_round_trip_through_text() {
    for s in STRING_POOL {
        let v = Value::Str((*s).to_string());
        let text = v.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back.as_str(), Some(*s), "{text}");
        assert_eq!(back.to_string(), text);
    }
    // Escaped input forms normalize to one canonical emitted form, which
    // is then a fixed point.
    let parsed = parse(r#""aA 😀 \/ \b\f""#).unwrap();
    assert_eq!(parsed.as_str(), Some("aA \u{1F600} / \u{0008}\u{000C}"));
    let text = parsed.to_string();
    assert_eq!(parse(&text).unwrap().to_string(), text);
}
