//! Acceptance tests for the multi-model serving fleet (ISSUE 4):
//!
//! 1. **byte identity** — a 1-model fleet produces responses byte-identical
//!    to the single-model `InferenceServer`, at any worker count;
//! 2. **no cross-routing** — every response is stamped by the deployment
//!    that served it, and per-model response streams equal the standalone
//!    oracles bit for bit;
//! 3. **cycle invariance** — per-model simulated cycle totals depend only
//!    on the request multiset, never on worker count, batch formation or
//!    interleaving;
//! 4. **shared-store warm start** — N models on one store dir restart with
//!    plan + shape warm loads (hit rate 1.0, zero `simulate_layer` calls),
//!    and cross-model shape reuse makes the shared-cache fleet strictly
//!    cheaper to cold-start than N isolated deployments;
//! 5. **hot add/remove** — models register and retire while the fleet is
//!    serving, without disturbing in-flight traffic.
//!
//! PR 5 adds the scheduling-policy layer: the default-policy fleet (the
//! byte-identity baseline above) **is** the Fifo policy, response *values*
//! are invariant under every policy (scheduling reorders batches, never
//! rewrites them), and `deadline-edf` accounting closes (served + missed
//! + dropped == offered).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;

use flex_tpu::config::ArchConfig;
use flex_tpu::inference::{
    Envelope, FleetServer, FleetStats, InferenceRequest, InferenceResponse, InferenceServer,
    ModelRegistry, PlanSource, SchedulePolicy, SimBackend,
};
use flex_tpu::sim::PlanStore;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("flex-tpu-fleet-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A deterministic request: pixels are a pure function of the id.
fn request(id: u64, model: &str) -> InferenceRequest {
    let pixels = (0..SimBackend::DIGEST_PIXELS)
        .map(|p| ((id as usize * 13 + p * 7) % 29) as f32 / 29.0)
        .collect();
    InferenceRequest {
        id,
        model: model.to_string(),
        pixels,
        deadline_us: None,
        priority: 0,
        seq_len: None,
    }
}

/// Push `requests` through a fleet on `workers` threads; responses come
/// back sorted by id (arrival order is a scheduling detail).
fn serve_fleet(
    fleet: &FleetServer,
    requests: &[InferenceRequest],
    workers: usize,
) -> (Vec<InferenceResponse>, FleetStats) {
    let (tx, rx) = mpsc::sync_channel::<Envelope>(16);
    let reqs = requests.to_vec();
    let producer = std::thread::spawn(move || {
        let mut rxs = Vec::new();
        for req in reqs {
            let (otx, orx) = mpsc::channel();
            tx.send((req, otx)).expect("fleet alive");
            rxs.push(orx);
        }
        drop(tx);
        rxs.into_iter()
            .map(|orx| orx.recv().expect("response"))
            .collect::<Vec<_>>()
    });
    let stats = fleet.serve(rx, workers).expect("fleet serves");
    let mut responses = producer.join().expect("producer join");
    responses.sort_by_key(|r| r.id);
    (responses, stats)
}

/// Push `requests` through a single-model server; responses sorted by id.
fn serve_single(
    server: &InferenceServer,
    requests: &[InferenceRequest],
    workers: usize,
) -> Vec<InferenceResponse> {
    let (tx, rx) = mpsc::sync_channel::<Envelope>(16);
    let reqs = requests.to_vec();
    let producer = std::thread::spawn(move || {
        let mut rxs = Vec::new();
        for req in reqs {
            let (otx, orx) = mpsc::channel();
            tx.send((req, otx)).expect("server alive");
            rxs.push(orx);
        }
        drop(tx);
        rxs.into_iter()
            .map(|orx| orx.recv().expect("response"))
            .collect::<Vec<_>>()
    });
    server.serve_concurrent(rx, workers).expect("server serves");
    let mut responses = producer.join().expect("producer join");
    responses.sort_by_key(|r| r.id);
    responses
}

#[test]
fn one_model_fleet_is_byte_identical_to_single_server() {
    let arch = ArchConfig::square(16);
    let backend = Arc::new(SimBackend::from_zoo("alexnet", 4).unwrap());
    let single = InferenceServer::from_backend(Arc::clone(&backend), arch, 1).unwrap();
    let requests: Vec<_> = (0..23).map(|id| request(id, "alexnet")).collect();
    let want = serve_single(&single, &requests, 1);
    assert_eq!(want.len(), 23);

    let registry = Arc::new(ModelRegistry::new(arch, None).unwrap());
    registry.register(backend).unwrap();
    let fleet = FleetServer::new(Arc::clone(&registry));
    for workers in [1usize, 2, 4] {
        let (got, stats) = serve_fleet(&fleet, &requests, workers);
        assert_eq!(got, want, "{workers} workers diverged from the single server");
        assert_eq!(stats.requests, 23);
        assert_eq!(stats.unknown_model, 0);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.per_model["alexnet"].requests, 23);
    }

    // The single server itself is worker-count invariant too.
    assert_eq!(serve_single(&single, &requests, 4), want);
}

#[test]
fn responses_are_never_cross_routed() {
    let arch = ArchConfig::square(16);
    let names = ["alexnet", "mobilenet", "yolo_tiny"];
    let registry = Arc::new(ModelRegistry::new(arch, None).unwrap());
    for name in names {
        registry
            .register(Arc::new(SimBackend::from_zoo(name, 3).unwrap()))
            .unwrap();
    }

    // Standalone per-model oracles over the same request subsets.
    let mut oracles: BTreeMap<&str, Vec<InferenceResponse>> = BTreeMap::new();
    for name in names {
        let backend = Arc::new(SimBackend::from_zoo(name, 3).unwrap());
        let server = InferenceServer::from_backend(backend, arch, 1).unwrap();
        let reqs: Vec<_> = (0..30u64)
            .filter(|id| names[(*id as usize) % 3] == name)
            .map(|id| request(id, name))
            .collect();
        oracles.insert(name, serve_single(&server, &reqs, 2));
    }

    let requests: Vec<_> = (0..30u64)
        .map(|id| request(id, names[(id as usize) % 3]))
        .collect();
    let fleet = FleetServer::new(Arc::clone(&registry));
    let (responses, stats) = serve_fleet(&fleet, &requests, 4);
    assert_eq!(responses.len(), 30);
    for resp in &responses {
        let expected = names[(resp.id as usize) % 3];
        assert_eq!(
            resp.model, expected,
            "request {} served by the wrong deployment",
            resp.id
        );
    }
    for name in names {
        let got: Vec<_> = responses
            .iter()
            .filter(|r| r.model == name)
            .cloned()
            .collect();
        assert_eq!(&got, oracles.get(name).unwrap(), "{name}");
        assert_eq!(stats.per_model[name].requests, 10);
    }
    assert_eq!(stats.per_model.len(), 3);
}

#[test]
fn per_model_cycle_totals_invariant_under_workers_and_interleaving() {
    let arch = ArchConfig::square(8);
    let names = ["alexnet", "mobilenet", "vgg13"];
    let registry = Arc::new(ModelRegistry::new(arch, None).unwrap());
    for name in names {
        registry
            .register(Arc::new(SimBackend::from_zoo(name, 2).unwrap()))
            .unwrap();
    }
    let fleet = FleetServer::new(Arc::clone(&registry));

    let round_robin: Vec<_> = (0..24u64)
        .map(|id| request(id, names[(id as usize) % 3]))
        .collect();
    let mut blocks = round_robin.clone();
    blocks.sort_by(|a, b| a.model.cmp(&b.model)); // per-model bursts

    let mut reference: Option<BTreeMap<String, u64>> = None;
    for (workers, reqs) in [
        (1usize, &round_robin),
        (4, &round_robin),
        (2, &blocks),
        (3, &blocks),
    ] {
        let (responses, stats) = serve_fleet(&fleet, reqs, workers);
        assert_eq!(responses.len(), 24);
        let cycles: BTreeMap<String, u64> = stats
            .per_model
            .iter()
            .map(|(k, m)| (k.clone(), m.sim_cycles_total))
            .collect();
        match &reference {
            None => reference = Some(cycles),
            Some(want) => assert_eq!(
                &cycles, want,
                "{workers} workers / interleaving changed cycle totals"
            ),
        }
    }

    // Totals are exactly what each deployment's plan predicts: 8 requests
    // per model × the per-inference flex cycles.
    let reference = reference.unwrap();
    for name in names {
        let dep = registry.get(name).unwrap();
        assert_eq!(reference[name], 8 * dep.server.timing().flex_cycles, "{name}");
    }
}

#[test]
fn shared_store_warm_start_and_cross_model_reuse() {
    let dir = tmpdir("warm");
    let arch = ArchConfig::square(16);
    // googlenet shares its stem conv with resnet18 and its classifier FC
    // with mobilenet — real cross-model shape reuse.
    let names = ["resnet18", "googlenet", "mobilenet"];
    let requests: Vec<_> = (0..18u64)
        .map(|id| request(id, names[(id as usize) % 3]))
        .collect();

    // Cold fleet: one shared cache, one store dir.
    let (cold_responses, cold_misses) = {
        let store = PlanStore::open(&dir).unwrap();
        let registry = Arc::new(ModelRegistry::new(arch, Some(store)).unwrap());
        for name in names {
            let dep = registry
                .register(Arc::new(SimBackend::from_zoo(name, 2).unwrap()))
                .unwrap();
            assert_eq!(dep.plan_source, PlanSource::Compiled, "{name}");
            assert_eq!(dep.shapes_preloaded, 0, "{name}");
        }
        let misses = registry.cache_stats().misses;
        assert!(misses > 0, "cold fleet must simulate");
        let fleet = FleetServer::new(Arc::clone(&registry));
        let (responses, _) = serve_fleet(&fleet, &requests, 2);
        (responses, misses)
    };

    // Isolated deployments pay strictly more cold simulations than the
    // shared-cache fleet (the reused shapes are simulated once per fleet,
    // once per model otherwise).
    let mut independent_misses = 0;
    for name in names {
        let registry = ModelRegistry::new(arch, None).unwrap();
        registry
            .register(Arc::new(SimBackend::from_zoo(name, 2).unwrap()))
            .unwrap();
        independent_misses += registry.cache_stats().misses;
    }
    assert!(
        cold_misses < independent_misses,
        "shared fleet {cold_misses} must beat isolated {independent_misses}"
    );

    // Restart against the same store: plans load, shapes preload, zero
    // simulate_layer calls, hit rate exactly 1.0, byte-identical serving.
    let store = PlanStore::open(&dir).unwrap();
    let registry = Arc::new(ModelRegistry::new(arch, Some(store)).unwrap());
    for name in names {
        let dep = registry
            .register(Arc::new(SimBackend::from_zoo(name, 2).unwrap()))
            .unwrap();
        assert_eq!(dep.plan_source, PlanSource::Loaded, "{name}");
        assert!(dep.shapes_preloaded > 0, "{name}");
    }
    let stats = registry.cache_stats();
    assert_eq!(stats.misses, 0, "warm fleet must not simulate: {stats:?}");
    assert!(stats.hits > 0);
    assert_eq!(stats.hit_rate(), 1.0);
    let fleet = FleetServer::new(Arc::clone(&registry));
    let (warm_responses, warm_stats) = serve_fleet(&fleet, &requests, 3);
    assert_eq!(warm_responses, cold_responses, "warm fleet output diverged");
    assert_eq!(warm_stats.requests, 18);
    assert_eq!(
        registry.cache_stats().misses,
        0,
        "serving a warm fleet must stay simulation-free"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn hot_add_and_remove_while_serving() {
    let arch = ArchConfig::square(8);
    let registry = Arc::new(ModelRegistry::new(arch, None).unwrap());
    registry
        .register(Arc::new(SimBackend::from_zoo("alexnet", 2).unwrap()))
        .unwrap();
    let fleet = FleetServer::new(Arc::clone(&registry));
    let (tx, rx) = mpsc::sync_channel::<Envelope>(32);
    let serving = std::thread::spawn(move || fleet.serve(rx, 2));

    // Phase 1: the initially registered model serves.
    let (otx, orx) = mpsc::channel();
    tx.send((request(0, "alexnet"), otx)).unwrap();
    assert_eq!(orx.recv().unwrap().model, "alexnet");

    // Phase 2: hot-add a second model mid-serve; it serves immediately.
    registry
        .register(Arc::new(SimBackend::from_zoo("vgg13", 2).unwrap()))
        .unwrap();
    let (otx, orx) = mpsc::channel();
    tx.send((request(1, "vgg13"), otx)).unwrap();
    assert_eq!(orx.recv().unwrap().model, "vgg13");

    // Phase 3: hot-remove the first model; its requests now drop cleanly
    // (the caller observes a closed response channel, not a hang).
    assert!(registry.remove("alexnet"));
    let (otx, orx) = mpsc::channel();
    tx.send((request(2, "alexnet"), otx)).unwrap();
    assert!(orx.recv().is_err(), "removed model must not serve");

    // The surviving model is unaffected.
    let (otx, orx) = mpsc::channel();
    tx.send((request(3, "vgg13"), otx)).unwrap();
    assert_eq!(orx.recv().unwrap().id, 3);

    drop(tx);
    let stats = serving.join().expect("serve thread").expect("serve ok");
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.unknown_model, 1);
}

#[test]
fn explicit_fifo_policy_is_the_default_fleet() {
    // `FleetServer::new` and `with_policy(Fifo)` are the same router: the
    // PR-4 byte-identity contract transfers to the policy layer verbatim.
    let arch = ArchConfig::square(16);
    let registry = Arc::new(ModelRegistry::new(arch, None).unwrap());
    registry
        .register(Arc::new(SimBackend::from_zoo("alexnet", 4).unwrap()))
        .unwrap();
    let requests: Vec<_> = (0..17).map(|id| request(id, "alexnet")).collect();
    let default_fleet = FleetServer::new(Arc::clone(&registry));
    let fifo_fleet = FleetServer::with_policy(Arc::clone(&registry), SchedulePolicy::Fifo);
    assert_eq!(default_fleet.policy(), SchedulePolicy::Fifo);
    let (want, want_stats) = serve_fleet(&default_fleet, &requests, 2);
    let (got, got_stats) = serve_fleet(&fifo_fleet, &requests, 2);
    assert_eq!(want, got);
    assert_eq!(want_stats.policy, "fifo");
    assert_eq!(got_stats.per_model["alexnet"].requests, 17);
    assert_eq!(got_stats.deadline_misses, 0);
}

#[test]
fn response_values_are_invariant_under_every_policy() {
    // Scheduling reorders batches; it must never change what any request
    // computes.  Same mixed stream through all three policies: the sorted
    // response sets are identical, every request is served (no deadlines
    // set), and the stats are stamped with the right policy name.
    let arch = ArchConfig::square(16);
    let names = ["alexnet", "mobilenet", "vgg13"];
    let registry = Arc::new(ModelRegistry::new(arch, None).unwrap());
    for name in names {
        registry
            .register(Arc::new(SimBackend::from_zoo(name, 3).unwrap()))
            .unwrap();
    }
    let requests: Vec<_> = (0..27u64)
        .map(|id| request(id, names[(id as usize) % 3]))
        .collect();
    let mut baseline: Option<Vec<InferenceResponse>> = None;
    for policy in SchedulePolicy::ALL {
        let fleet = FleetServer::with_policy(Arc::clone(&registry), policy);
        let (responses, stats) = serve_fleet(&fleet, &requests, 3);
        assert_eq!(stats.policy, policy.name());
        assert_eq!(stats.requests, 27, "{policy}");
        assert_eq!(stats.deadline_misses, 0, "{policy}: no deadlines set");
        assert!(
            stats.per_model.values().all(|m| m.reconfigurations > 0),
            "{policy}: reconfiguration accounting must be live"
        );
        match &baseline {
            None => baseline = Some(responses),
            Some(want) => assert_eq!(&responses, want, "{policy} changed response values"),
        }
    }
}

#[test]
fn edf_accounting_closes_under_tight_deadlines() {
    // Every request carries a 1 µs budget: whether each one launches in
    // time is host-timing luck, but the books must always close — every
    // request is either served or counted as a deadline miss, and a
    // missed request's response channel reads as closed, never as a hang.
    let arch = ArchConfig::square(8);
    let registry = Arc::new(ModelRegistry::new(arch, None).unwrap());
    registry
        .register(Arc::new(SimBackend::from_zoo("mobilenet", 4).unwrap()))
        .unwrap();
    let fleet = FleetServer::with_policy(Arc::clone(&registry), SchedulePolicy::DeadlineEdf);

    let total = 40u64;
    let (tx, rx) = mpsc::sync_channel::<Envelope>(8);
    let producer = std::thread::spawn(move || {
        let mut rxs = Vec::new();
        for id in 0..total {
            let mut req = request(id, "mobilenet");
            req.deadline_us = Some(1);
            let (otx, orx) = mpsc::channel();
            tx.send((req, otx)).expect("fleet alive");
            rxs.push(orx);
            if id % 8 == 7 {
                // Let the router go dry now and then so partial batches
                // (and expiry sweeps) actually happen mid-stream.
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        drop(tx);
        rxs.into_iter().filter(|orx| orx.recv().is_ok()).count() as u64
    });
    let stats = fleet.serve(rx, 2).expect("serve ok");
    let delivered = producer.join().expect("producer join");
    assert_eq!(stats.policy, "deadline-edf");
    assert_eq!(delivered, stats.requests, "every served request is delivered");
    assert_eq!(
        stats.requests + stats.deadline_misses,
        total,
        "served + missed must cover the offered stream"
    );
    let m = &stats.per_model["mobilenet"];
    assert_eq!(m.requests + m.deadline_misses, total);
}

#[test]
fn malformed_requests_are_rejected_not_fatal() {
    let arch = ArchConfig::square(8);
    let registry = Arc::new(ModelRegistry::new(arch, None).unwrap());
    registry
        .register(Arc::new(SimBackend::from_zoo("alexnet", 2).unwrap()))
        .unwrap();
    let fleet = FleetServer::new(Arc::clone(&registry));

    let (tx, rx) = mpsc::sync_channel::<Envelope>(8);
    let producer = std::thread::spawn(move || {
        // Wrong pixel count: dropped at the front door.
        let (otx, bad_rx) = mpsc::channel();
        let bad = InferenceRequest {
            id: 0,
            model: "alexnet".to_string(),
            pixels: vec![0.0; 3],
            deadline_us: None,
            priority: 0,
            seq_len: None,
        };
        tx.send((bad, otx)).unwrap();
        // A well-formed request behind it still serves.
        let (otx, good_rx) = mpsc::channel();
        tx.send((request(1, "alexnet"), otx)).unwrap();
        drop(tx);
        (bad_rx.recv().is_err(), good_rx.recv())
    });
    let stats = fleet.serve(rx, 1).expect("serve ok");
    let (bad_dropped, good) = producer.join().unwrap();
    assert!(bad_dropped, "malformed request must be dropped");
    assert_eq!(good.expect("good response").id, 1);
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.requests, 1);
}
