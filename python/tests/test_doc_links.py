"""Docs-link check: relative links in the durable docs must resolve.

Scans the maintained documentation set (architecture, workload taxonomy,
CLI reference, roadmap) for markdown links and verifies every relative
target exists in the checkout.  External URLs and pure anchors are left
alone.  Also pins the ISSUE-10 cross-linking contract: the workload
taxonomy is reachable from both the CLI README and ARCHITECTURE.md.

Dependency-free (stdlib only) so it runs on minimal CI runners.
"""

import os
import re

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", ".."))

# The durable docs: new documentation must be added here to get link
# checking (paper dumps like PAPERS.md / SNIPPETS.md are excluded — they
# quote external material verbatim).
DOCS = [
    "ARCHITECTURE.md",
    "WORKLOADS.md",
    "ROADMAP.md",
    "rust/README.md",
]

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _links(doc):
    with open(os.path.join(REPO_ROOT, doc)) as f:
        text = f.read()
    # Strip fenced code blocks: CLI examples legitimately contain
    # bracket-paren sequences that are not links.
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return LINK.findall(text)


def test_all_docs_exist():
    for doc in DOCS:
        assert os.path.isfile(os.path.join(REPO_ROOT, doc)), doc


def test_relative_links_resolve():
    broken = []
    for doc in DOCS:
        base = os.path.dirname(os.path.join(REPO_ROOT, doc))
        for target in _links(doc):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not os.path.exists(os.path.normpath(os.path.join(base, path))):
                broken.append("%s -> %s" % (doc, target))
    assert not broken, "broken relative links:\n" + "\n".join(broken)


def test_workloads_taxonomy_is_cross_linked():
    for doc in ["ARCHITECTURE.md", "rust/README.md"]:
        targets = [t.split("#", 1)[0] for t in _links(doc)]
        assert any(t.endswith("WORKLOADS.md") for t in targets), (
            "%s must link to the workload taxonomy (WORKLOADS.md)" % doc
        )
