"""Environment and cross-layer consistency checks that run with or without
JAX installed (the rest of the suite auto-skips via the root conftest)."""

import importlib.util
import os

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..", "..")


def test_compile_package_importable():
    """conftest puts python/ on sys.path; the compile package must resolve."""
    assert importlib.util.find_spec("compile.kernels") is not None
    assert importlib.util.find_spec("compile.kernels.ref") is not None


def test_zoo_topologies_present_and_well_formed():
    """The rust zoo embeds topologies/*.csv at compile time; keep the file
    set and the ScaleSim 8-field row format in sync from the python side."""
    topo_dir = os.path.join(REPO_ROOT, "topologies")
    expected = {
        "alexnet",
        "faster_rcnn",
        "googlenet",
        "mobilenet",
        "resnet18",
        "vgg13",
        "yolo_tiny",
    }
    have = {
        os.path.splitext(f)[0] for f in os.listdir(topo_dir) if f.endswith(".csv")
    }
    assert expected <= have, f"missing topologies: {expected - have}"
    for name in sorted(expected):
        with open(os.path.join(topo_dir, name + ".csv")) as f:
            lines = [l.strip() for l in f if l.strip()]
        assert "layer" in lines[0].lower(), f"{name}: missing header"
        for row in lines[1:]:
            fields = [x.strip() for x in row.split(",") if x.strip()]
            assert len(fields) == 8, f"{name}: bad row {row!r}"
            ih, iw, fh, fw, c, n, s = map(int, fields[1:8])
            assert s >= 1 and fh <= ih and fw <= iw, f"{name}: bad geometry {row!r}"


def test_jax_skip_guard_is_honest():
    """The root conftest must skip the JAX suites exactly when jax or
    hypothesis is missing — never when both are importable."""
    import conftest

    missing = [
        m for m in ("jax", "hypothesis") if importlib.util.find_spec(m) is None
    ]
    expected = (
        ["python/tests/test_kernel.py", "python/tests/test_model.py"]
        if missing
        else []
    )
    assert conftest.collect_ignore == expected
