"""L1 correctness: Pallas systolic kernels vs the pure-jnp oracle.

This is the CORE correctness signal for the functional path — every dataflow
schedule (OS/WS/IS) must compute the identical GEMM.  Hypothesis sweeps
shapes/dtypes; fixed cases pin the block-edge and padding corners.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, systolic

DATAFLOWS = ("os", "ws", "is")


def _rand(shape, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    if dtype == jnp.int8:
        return (x * 10).astype(jnp.int8)
    return x.astype(dtype)


@pytest.mark.parametrize("dataflow", DATAFLOWS)
@pytest.mark.parametrize(
    "m,k,n",
    [
        (8, 8, 8),        # single block
        (16, 8, 8),       # multi-fold on M
        (8, 16, 8),       # multi-fold on K (accumulation across grid steps)
        (8, 8, 16),       # multi-fold on N
        (32, 24, 40),     # multi-fold on all dims
        (5, 7, 3),        # ragged: exercises zero-padding + slice-back
        (1, 256, 10),     # FC-shaped degenerate M=1 GEMM
        (130, 129, 131),  # just past the default 128 block edge
    ],
)
def test_matmul_matches_ref(dataflow, m, k, n):
    a = _rand((m, k), jnp.float32, 0)
    b = _rand((k, n), jnp.float32, 1)
    got = systolic.matmul(a, b, dataflow=dataflow, block_m=8, block_n=8, block_k=8)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dataflow", DATAFLOWS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_matmul_dtypes(dataflow, dtype):
    a = _rand((16, 24), dtype, 2)
    b = _rand((24, 8), dtype, 3)
    got = systolic.matmul(a, b, dataflow=dataflow, block_m=8, block_n=8, block_k=8)
    want = ref.matmul_ref(a, b)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol, atol=tol
    )


def test_dataflows_agree_exactly():
    """The paper's core functional claim: dataflow changes time, not values.

    All three schedules accumulate over K in the same block order, so the
    results must agree bit-for-bit, not just within tolerance.
    """
    a = _rand((40, 56), jnp.float32, 4)
    b = _rand((56, 24), jnp.float32, 5)
    outs = [
        np.asarray(systolic.matmul(a, b, dataflow=d, block_m=8, block_n=8, block_k=8))
        for d in DATAFLOWS
    ]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


@pytest.mark.parametrize("dataflow", DATAFLOWS)
def test_matmul_bias_relu(dataflow):
    a = _rand((12, 20), jnp.float32, 6)
    b = _rand((20, 8), jnp.float32, 7)
    bias = _rand((8,), jnp.float32, 8)
    got = systolic.matmul_bias_relu(
        a, b, bias, dataflow=dataflow, block_m=8, block_n=8, block_k=8
    )
    want = ref.matmul_bias_relu_ref(a, b, bias)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert (np.asarray(got) >= 0).all()


@pytest.mark.parametrize("dataflow", DATAFLOWS)
@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (12, 24, 9), (1, 40, 16), (17, 5, 3)])
def test_fused_epilogue_matches_ref(dataflow, m, k, n):
    """The in-kernel bias+ReLU epilogue (applied on the final K-step visit)
    must match the unfused oracle for every schedule and fold pattern."""
    a = _rand((m, k), jnp.float32, 10)
    b = _rand((k, n), jnp.float32, 11)
    bias = _rand((n,), jnp.float32, 12)
    got = systolic.matmul_bias_relu(
        a, b, bias, dataflow=dataflow, block_m=8, block_n=8, block_k=8
    )
    want = ref.matmul_bias_relu_ref(a, b, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fused_bad_bias_shape_raises():
    a = jnp.zeros((8, 8))
    with pytest.raises(ValueError):
        systolic.matmul_bias_relu(a, a, jnp.zeros((4,)))


@pytest.mark.parametrize("dataflow", DATAFLOWS)
def test_quantized_matmul_exact(dataflow):
    """INT8 x INT8 -> INT32 accumulation is exact, so the dequantized result
    must equal the oracle bit-for-bit (no float tolerance)."""
    a = _rand((13, 22), jnp.int8, 20)
    b = _rand((22, 7), jnp.int8, 21)
    got = systolic.quantized_matmul(
        a, b, scale_a=0.5, scale_b=0.125, dataflow=dataflow,
        block_m=8, block_n=8, block_k=8,
    )
    want = ref.quantized_matmul_ref(a, b, 0.5, 0.125)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantized_rejects_float_inputs():
    a = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(ValueError):
        systolic.quantized_matmul(a, a.astype(jnp.int8))
    with pytest.raises(ValueError):
        systolic.quantized_matmul(
            jnp.zeros((4, 5), jnp.int8), jnp.zeros((4, 5), jnp.int8)
        )


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 32),
    k=st.integers(1, 32),
    n=st.integers(1, 32),
    dataflow=st.sampled_from(DATAFLOWS),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantized_property(m, k, n, dataflow, seed):
    """Hypothesis: quantized GEMM exact for arbitrary shapes/schedules."""
    a = _rand((m, k), jnp.int8, seed)
    b = _rand((k, n), jnp.int8, seed + 1)
    got = systolic.quantized_matmul(
        a, b, dataflow=dataflow, block_m=8, block_n=8, block_k=8
    )
    want = ref.quantized_matmul_ref(a, b, 1.0, 1.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bad_shapes_raise():
    a = jnp.zeros((4, 5))
    b = jnp.zeros((6, 4))
    for dataflow in DATAFLOWS:
        with pytest.raises(ValueError):
            systolic.matmul(a, b, dataflow=dataflow)
    with pytest.raises(ValueError):
        systolic.matmul(jnp.zeros((4,)), jnp.zeros((4, 4)))


def test_unknown_dataflow_raises():
    a = jnp.zeros((8, 8))
    with pytest.raises(ValueError):
        systolic.matmul(a, a, dataflow="nope")


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    dataflow=st.sampled_from(DATAFLOWS),
    block=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_property(m, k, n, dataflow, block, seed):
    """Hypothesis sweep: arbitrary shapes/blocks/dataflow vs oracle."""
    a = _rand((m, k), jnp.float32, seed)
    b = _rand((k, n), jnp.float32, seed + 1)
    got = systolic.matmul(
        a, b, dataflow=dataflow, block_m=block, block_n=block, block_k=block
    )
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
