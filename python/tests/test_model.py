"""L2 correctness: im2col layout, conv lowering, and full-model invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _img(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@pytest.mark.parametrize(
    "h,w,c,kh,kw,stride,padding",
    [
        (6, 6, 3, 3, 3, 1, 1),
        (8, 8, 2, 3, 3, 2, 1),
        (7, 5, 4, 1, 1, 1, 0),   # pointwise
        (9, 9, 1, 5, 5, 1, 2),
        (8, 8, 3, 3, 3, 2, 0),   # no padding, strided
    ],
)
def test_im2col_matches_ref(h, w, c, kh, kw, stride, padding):
    """model._im2col's strided-slice construction == per-pixel oracle."""
    x = _img((h, w, c))
    got = model._im2col(x, kh, kw, stride, padding)
    want = ref.im2col_ref(x, kh, kw, stride, padding)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dataflow", ["os", "ws", "is"])
def test_conv2d_matches_ref(dataflow):
    x = _img((8, 8, 3), 1)
    w = _img((3, 3, 3, 4), 2) * 0.2
    b = _img((4,), 3)
    got = model.conv2d(x, w, b, stride=1, padding=1, dataflow=dataflow)
    want = ref.conv2d_ref(x, w, b, stride=1, padding=1)
    assert got.shape == (8, 8, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_avgpool_matches_ref():
    x = _img((8, 8, 5), 4)
    np.testing.assert_allclose(
        np.asarray(model.avgpool(x, 2)), np.asarray(ref.avgpool_ref(x, 2)),
        rtol=1e-6, atol=1e-6,
    )


def test_forward_shapes():
    params = model.init_params(0)
    x = _img((model.INPUT_HW, model.INPUT_HW, 3), 5)
    logits = model.forward_single(params, x)
    assert logits.shape == (model.NUM_CLASSES,)
    xs = _img((model.BATCH, model.INPUT_HW, model.INPUT_HW, 3), 6)
    batch_logits = model.forward_batch(params, xs)
    assert batch_logits.shape == (model.BATCH, model.NUM_CLASSES)


def test_forward_batch_consistent_with_single():
    params = model.init_params(0)
    xs = _img((3, model.INPUT_HW, model.INPUT_HW, 3), 7)
    batched = model.forward_batch(params, xs[: model.BATCH])
    for i in range(3):
        single = model.forward_single(params, xs[i])
        np.testing.assert_allclose(
            np.asarray(batched[i]), np.asarray(single), rtol=1e-5, atol=1e-5
        )


def test_dataflow_variants_agree():
    """Static-OS/WS/IS and the flex per-layer table give identical logits —
    the functional statement of 'reconfiguration changes time, not math'."""
    params = model.init_params(0)
    x = _img((model.INPUT_HW, model.INPUT_HW, 3), 8)
    base = model.forward_single(params, x, ["os", "os", "os"])
    for dfs in (["ws", "ws", "ws"], ["is", "is", "is"], list(model.DEFAULT_DATAFLOWS)):
        other = model.forward_single(params, x, dfs)
        np.testing.assert_allclose(
            np.asarray(base), np.asarray(other), rtol=1e-5, atol=1e-5
        )


def test_init_params_deterministic():
    p1 = model.init_params(0)
    p2 = model.init_params(0)
    np.testing.assert_array_equal(
        np.asarray(p1["conv1"]["w"]), np.asarray(p2["conv1"]["w"])
    )
    p3 = model.init_params(1)
    assert not np.array_equal(
        np.asarray(p1["conv1"]["w"]), np.asarray(p3["conv1"]["w"])
    )


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(4, 10),
    c=st.integers(1, 4),
    cout=st.integers(1, 6),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 1000),
)
def test_conv2d_property(h, c, cout, stride, seed):
    """Hypothesis: conv via systolic GEMM == direct oracle for random geometry."""
    x = _img((h, h, c), seed)
    w = _img((3, 3, c, cout), seed + 1) * 0.3
    b = _img((cout,), seed + 2)
    got = model.conv2d(x, w, b, stride=stride, padding=1, dataflow="os")
    want = ref.conv2d_ref(x, w, b, stride=stride, padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
