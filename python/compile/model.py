"""L2: the JAX CNN whose GEMMs flow through the L1 Pallas systolic kernels.

This is the functional half of the Flex-TPU reproduction: the cycle-accurate
simulator (rust L3) provides *time*; this model, AOT-lowered to HLO and run
by the rust PJRT runtime, provides *values*.  The network ("FlexNet-Tiny")
is a small conv-net sized so the interpret-mode Pallas lowering stays cheap
while still exercising conv -> im2col -> GEMM -> bias/ReLU -> pool -> FC,
i.e. every layer shape class the paper's workloads contain.

Every conv/FC is lowered onto kernels.systolic.matmul_bias_relu with a
per-layer dataflow argument — the software twin of the CMU reconfiguring the
array per layer.  Python runs only at build time (make artifacts).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels import systolic

# (name, kh, kw, cin, cout, stride, padding) for the conv trunk.
CONV_LAYERS = (
    ("conv1", 3, 3, 3, 8, 1, 1),
    ("conv2", 3, 3, 8, 16, 1, 1),
)
INPUT_HW = 16  # 16x16x3 inputs
POOL = 2
NUM_CLASSES = 10
FC_IN = (INPUT_HW // POOL // POOL) ** 2 * CONV_LAYERS[-1][4]  # 4*4*16 = 256
BATCH = 8

# Per-layer dataflow baked into the exported artifact (the rust CMU owns the
# authoritative table and picks which artifact variant to execute).
# Order: conv1, conv2, fc.
DEFAULT_DATAFLOWS: Sequence[systolic.Dataflow] = ("ws", "os", "is")


def init_params(seed: int = 0) -> dict:
    """Deterministic He-style init; synthetic weights (see DESIGN.md §6)."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, kh, kw, cin, cout, _s, _p in CONV_LAYERS:
        key, wkey = jax.random.split(key)
        fan_in = kh * kw * cin
        params[name] = {
            "w": jax.random.normal(wkey, (kh, kw, cin, cout), jnp.float32)
            * jnp.sqrt(2.0 / fan_in),
            "b": jnp.zeros((cout,), jnp.float32),
        }
    key, fc_key = jax.random.split(key)
    params["fc"] = {
        "w": jax.random.normal(fc_key, (FC_IN, NUM_CLASSES), jnp.float32)
        * jnp.sqrt(2.0 / FC_IN),
        "b": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }
    return params


def _im2col(x: jnp.ndarray, kh: int, kw: int, stride: int, padding: int) -> jnp.ndarray:
    """(H, W, C) -> (out_h*out_w, kh*kw*C) patch matrix, (dy, dx, c) order.

    Matches kernels.ref.im2col_ref exactly (tested), but builds the patch
    matrix from kh*kw strided slices instead of a per-pixel python loop so
    tracing stays O(kernel size), not O(output pixels).
    """
    h, w, _c = x.shape
    xp = jnp.pad(x, ((padding, padding), (padding, padding), (0, 0)))
    out_h = (h + 2 * padding - kh) // stride + 1
    out_w = (w + 2 * padding - kw) // stride + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(
                xp[dy : dy + out_h * stride : stride, dx : dx + out_w * stride : stride, :]
            )
    patches = jnp.stack(cols, axis=2)  # (out_h, out_w, kh*kw, C)
    return patches.reshape(out_h * out_w, kh * kw * _c)


def conv2d(x, w, b, stride: int, padding: int, dataflow: systolic.Dataflow):
    """Conv+bias+ReLU on one sample via im2col + the systolic GEMM kernel."""
    kh, kw, cin, cout = w.shape
    h, wdt, _ = x.shape
    out_h = (h + 2 * padding - kh) // stride + 1
    out_w = (wdt + 2 * padding - kw) // stride + 1
    patches = _im2col(x, kh, kw, stride, padding)  # (M, K)
    wmat = w.reshape(kh * kw * cin, cout)  # (K, N)
    y = systolic.matmul_bias_relu(patches, wmat, b, dataflow=dataflow)
    return y.reshape(out_h, out_w, cout)


def avgpool(x: jnp.ndarray, pool: int) -> jnp.ndarray:
    h, w, c = x.shape
    return x.reshape(h // pool, pool, w // pool, pool, c).mean(axis=(1, 3))


def forward_single(
    params: dict,
    x: jnp.ndarray,
    dataflows: Sequence[systolic.Dataflow] = DEFAULT_DATAFLOWS,
) -> jnp.ndarray:
    """Logits for one (H, W, 3) image."""
    df = list(dataflows)
    for i, (name, _kh, _kw, _cin, _cout, stride, padding) in enumerate(CONV_LAYERS):
        p = params[name]
        x = conv2d(x, p["w"], p["b"], stride, padding, df[i])
        x = avgpool(x, POOL)
    flat = x.reshape(1, -1)  # (1, FC_IN): FC is a degenerate M=1 GEMM
    logits = systolic.matmul(flat, params["fc"]["w"], dataflow=df[-1])
    return (logits + params["fc"]["b"])[0]


def forward_batch(params: dict, xs: jnp.ndarray,
                  dataflows: Sequence[systolic.Dataflow] = DEFAULT_DATAFLOWS):
    """Logits for a (B, H, W, 3) batch (vmapped single-sample forward)."""
    return jax.vmap(lambda x: forward_single(params, x, dataflows))(xs)
