"""Pure-jnp correctness oracles for the Pallas systolic-GEMM kernels.

These are the ground truth the L1 kernels are tested against (pytest +
hypothesis in python/tests/). They intentionally use nothing but jnp so they
lower to stock XLA ops and cannot share bugs with the Pallas schedules.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain GEMM oracle: (M,K) @ (K,N) -> (M,N) in f32 accumulation."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def matmul_bias_relu_ref(a, b, bias):
    """GEMM + bias + ReLU oracle (the fused epilogue used by conv layers)."""
    y = matmul_ref(a, b) + bias.astype(jnp.float32)
    return jnp.maximum(y, 0.0)


def im2col_ref(x, kh: int, kw: int, stride: int, padding: int):
    """Explicit im2col patch extraction oracle.

    x: (H, W, C) -> (out_h * out_w, kh * kw * C) patch matrix, matching the
    layout produced by model._im2col (rows = output pixels in row-major
    order, cols = (dy, dx, c) in row-major order).
    """
    h, w, c = x.shape
    xp = jnp.pad(x, ((padding, padding), (padding, padding), (0, 0)))
    out_h = (h + 2 * padding - kh) // stride + 1
    out_w = (w + 2 * padding - kw) // stride + 1
    rows = []
    for oy in range(out_h):
        for ox in range(out_w):
            patch = xp[oy * stride : oy * stride + kh, ox * stride : ox * stride + kw, :]
            rows.append(patch.reshape(-1))
    return jnp.stack(rows, axis=0)


def conv2d_ref(x, w, bias, stride: int, padding: int):
    """Direct convolution oracle via im2col + GEMM.

    x: (H, W, Cin), w: (KH, KW, Cin, Cout), bias: (Cout,)
    returns (out_h, out_w, Cout) after ReLU.
    """
    kh, kw, cin, cout = w.shape
    h, wdt, _ = x.shape
    patches = im2col_ref(x, kh, kw, stride, padding)  # (M, K)
    wmat = w.reshape(kh * kw * cin, cout)  # (K, N)
    out_h = (h + 2 * padding - kh) // stride + 1
    out_w = (wdt + 2 * padding - kw) // stride + 1
    y = matmul_bias_relu_ref(patches, wmat, bias)
    return y.reshape(out_h, out_w, cout)


def quantized_matmul_ref(a, b, scale_a: float, scale_b: float):
    """Exact int32-accumulation quantized GEMM oracle."""
    acc = jnp.matmul(a.astype(jnp.int32), b.astype(jnp.int32))
    return acc.astype(jnp.float32) * (scale_a * scale_b)


def avgpool_ref(x, pool: int):
    """Non-overlapping average pool oracle. x: (H, W, C)."""
    h, w, c = x.shape
    return x[: h - h % pool, : w - w % pool, :].reshape(
        h // pool, pool, w // pool, pool, c
    ).mean(axis=(1, 3))
