"""L1 Pallas kernels: the systolic GEMM hot-spot under three dataflow schedules.

The Flex-TPU paper reconfigures a systolic array between input-stationary
(IS), output-stationary (OS) and weight-stationary (WS) dataflows per layer.
On a TPU the analogue of "which operand is pinned in PE registers" is "which
operand block stays resident in VMEM across the inner grid loop".  Each
schedule below expresses one dataflow through Pallas grid ordering and
BlockSpec index maps (see DESIGN.md §7 Hardware-Adaptation):

  OS: grid (m, n, k), k innermost  -> the OUTPUT block (m, n) is revisited
      every k step and accumulated in place: outputs stationary.
  WS: grid (n, k, m), m innermost  -> the WEIGHT block index map (k, n)
      ignores m: weights stationary while activations stream.
  IS: grid (m, k, n), n innermost  -> the ACTIVATION block index map (m, k)
      ignores n: inputs stationary while weights stream.

All kernels compute the same GEMM (bit-identical up to f32 accumulation
order) and are verified against kernels.ref by pytest + hypothesis.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernels lower to plain HLO (see aot_recipe /
/opt/xla-example/README.md).  Real-TPU VMEM/MXU estimates: DESIGN.md §9.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Dataflow = Literal["os", "ws", "is"]

# MXU-aligned default; small blocks are allowed (tests use 8/16) since
# interpret mode has no hardware tiling constraint.
DEFAULT_BLOCK = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad2(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------


def _os_body(a_ref, b_ref, o_ref, *, k_steps: int):
    """Output-stationary: o block pinned across the innermost k loop."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _ws_body(a_ref, b_ref, o_ref, *, k_steps: int):
    """Weight-stationary: b block constant across the innermost m loop.

    Grid is (n, k, m); the output block (m, n) is revisited once per k step
    (middle dim), so zero-init at k == 0 and accumulate after.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _is_body(a_ref, b_ref, o_ref, *, k_steps: int):
    """Input-stationary: a block constant across the innermost n loop."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# schedules: grid + BlockSpecs per dataflow
# ---------------------------------------------------------------------------


def _schedule(dataflow: Dataflow, mt: int, nt: int, kt: int, bm: int, bn: int, bk: int):
    """Return (body, grid, a_spec, b_spec, o_spec) for one dataflow."""
    if dataflow == "os":
        # grid (m, n, k); output (m, n) ignores k -> stationary output block
        return (
            functools.partial(_os_body, k_steps=kt),
            (mt, nt, kt),
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        )
    if dataflow == "ws":
        # grid (n, k, m); weight (k, n) ignores m -> stationary weight block
        return (
            functools.partial(_ws_body, k_steps=kt),
            (nt, kt, mt),
            pl.BlockSpec((bm, bk), lambda n, k, m: (m, k)),
            pl.BlockSpec((bk, bn), lambda n, k, m: (k, n)),
            pl.BlockSpec((bm, bn), lambda n, k, m: (m, n)),
        )
    if dataflow == "is":
        # grid (m, k, n); activation (m, k) ignores n -> stationary input block
        return (
            functools.partial(_is_body, k_steps=kt),
            (mt, kt, nt),
            pl.BlockSpec((bm, bk), lambda m, k, n: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, k, n: (k, n)),
            pl.BlockSpec((bm, bn), lambda m, k, n: (m, n)),
        )
    raise ValueError(f"unknown dataflow {dataflow!r}")


def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    dataflow: Dataflow = "os",
    block_m: int = DEFAULT_BLOCK,
    block_n: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
) -> jnp.ndarray:
    """Systolic GEMM (M,K)@(K,N)->(M,N) under the given dataflow schedule.

    Inputs may be f32/bf16/int8; accumulation is f32 and the result is f32.
    Shapes need not be block-aligned; operands are zero-padded up and the
    result sliced back (zero padding is exact for matmul).
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = (min(block_m, _ceil_to(m, 8)), min(block_n, _ceil_to(n, 8)),
                  min(block_k, _ceil_to(k, 8)))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    ap = _pad2(a, mp, kp)
    bp = _pad2(b, kp, np_)
    mt, nt, kt = mp // bm, np_ // bn, kp // bk

    body, grid, a_spec, b_spec, o_spec = _schedule(dataflow, mt, nt, kt, bm, bn, bk)
    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[a_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


def _fused_body(a_ref, b_ref, bias_ref, o_ref, *, k_axis: int, k_steps: int):
    """GEMM body with the bias+ReLU epilogue fused into the final K step.

    The output block stays resident across the K grid dimension (whichever
    grid axis that is for the schedule); on its last visit the accumulated
    block gets bias added and ReLU applied in place — the systolic-array
    analogue of folding the activation into the drain path.
    """
    k = pl.program_id(k_axis)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        o_ref[...] = jnp.maximum(o_ref[...] + bias_ref[...], 0.0)


def matmul_bias_relu(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    dataflow: Dataflow = "os",
    block_m: int = DEFAULT_BLOCK,
    block_n: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
) -> jnp.ndarray:
    """GEMM with the bias+ReLU epilogue fused *inside* the Pallas kernel.

    Used by every conv/FC layer of the L2 model.  The epilogue fires on the
    output block's final K-step visit, so no extra pass over the output is
    needed (and on a real TPU no extra HBM round-trip).
    """
    if bias.shape != (b.shape[1],):
        raise ValueError(f"bias shape {bias.shape} != ({b.shape[1]},)")
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = (min(block_m, _ceil_to(m, 8)), min(block_n, _ceil_to(n, 8)),
                  min(block_k, _ceil_to(k, 8)))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    ap = _pad2(a, mp, kp)
    bp = _pad2(b, kp, np_)
    biasp = jnp.pad(bias.astype(jnp.float32), (0, np_ - n)).reshape(1, np_)
    mt, nt, kt = mp // bm, np_ // bn, kp // bk

    _, grid, a_spec, b_spec, o_spec = _schedule(dataflow, mt, nt, kt, bm, bn, bk)
    # K grid-axis index per schedule: OS has k innermost (2), WS/IS middle (1).
    k_axis = 2 if dataflow == "os" else 1
    # Bias block follows the output's N index under each schedule.
    if dataflow == "os":
        bias_spec = pl.BlockSpec((1, bn), lambda m_, n_, k_: (0, n_))
    elif dataflow == "ws":
        bias_spec = pl.BlockSpec((1, bn), lambda n_, k_, m_: (0, n_))
    else:  # is
        bias_spec = pl.BlockSpec((1, bn), lambda m_, k_, n_: (0, n_))

    out = pl.pallas_call(
        functools.partial(_fused_body, k_axis=k_axis, k_steps=kt),
        grid=grid,
        in_specs=[a_spec, b_spec, bias_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(ap, bp, biasp)
    return out[:m, :n]


def _quantized_body(a_ref, b_ref, o_ref, *, k_axis: int):
    """INT8 x INT8 -> INT32 accumulation (Edge-TPU datapath)."""
    k = pl.program_id(k_axis)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.int32),
        b_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def quantized_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    scale_a: float = 1.0,
    scale_b: float = 1.0,
    dataflow: Dataflow = "os",
    block_m: int = DEFAULT_BLOCK,
    block_n: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
) -> jnp.ndarray:
    """Quantized GEMM: int8 operands, exact int32 accumulation, dequantized
    float output (`scale_a * scale_b * (a_int @ b_int)`).

    Mirrors the INT8 MAC datapath of the paper's PEs (and of the functional
    rust array in `rust/src/arch/`), under any of the three schedules.
    """
    if a.dtype != jnp.int8 or b.dtype != jnp.int8:
        raise ValueError(f"quantized_matmul expects int8, got {a.dtype}/{b.dtype}")
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = (min(block_m, _ceil_to(m, 8)), min(block_n, _ceil_to(n, 8)),
                  min(block_k, _ceil_to(k, 8)))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    ap = _pad2(a, mp, kp)
    bp = _pad2(b, kp, np_)
    mt, nt, kt = mp // bm, np_ // bn, kp // bk

    _, grid, a_spec, b_spec, o_spec = _schedule(dataflow, mt, nt, kt, bm, bn, bk)
    k_axis = 2 if dataflow == "os" else 1
    acc = pl.pallas_call(
        functools.partial(_quantized_body, k_axis=k_axis),
        grid=grid,
        in_specs=[a_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(ap, bp)
    return acc[:m, :n].astype(jnp.float32) * (scale_a * scale_b)
