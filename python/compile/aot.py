"""AOT compile path: lower the L2 model + L1 kernels to HLO text artifacts.

Interchange format is HLO *text*, NOT serialized HloModuleProto and NOT
jax.export bytes: jax >= 0.5 emits protos with 64-bit instruction ids which
the xla crate's runtime (xla_extension 0.5.1) rejects (`proto.id() <=
INT_MAX`); the HLO text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md and gen_hlo.py there.

Artifacts (written to --outdir, default ../artifacts):
  model_flex.hlo.txt     FlexNet-Tiny fwd, per-layer dataflows from the CMU
  model_os.hlo.txt       static-OS baseline (same math; the rust e2e example
  model_ws.hlo.txt       asserts all variants agree bitwise-ish, mirroring
  model_is.hlo.txt       the paper's claim that dataflow only changes time)
  gemm_{os,ws,is}.hlo.txt  64x64x64 GEMM per dataflow for runtime tests
  manifest.json          shapes + dataflow tables for the rust loader

Weights are baked into the HLO as constants (seed-0 init): the rust request
path passes only the input batch.  Python never runs at request time.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import systolic

GEMM_DIM = 64
DATAFLOWS = ("os", "ws", "is")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(params, dataflows) -> str:
    def fwd(xs):
        return (model.forward_batch(params, xs, dataflows),)

    spec = jax.ShapeDtypeStruct(
        (model.BATCH, model.INPUT_HW, model.INPUT_HW, 3), jnp.float32
    )
    return to_hlo_text(jax.jit(fwd).lower(spec))


def lower_gemm(dataflow: str, dim: int = GEMM_DIM) -> str:
    def fn(a, b):
        return (systolic.matmul(a, b, dataflow=dataflow,
                                block_m=32, block_n=32, block_k=32),)

    spec = jax.ShapeDtypeStruct((dim, dim), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    params = model.init_params(args.seed)
    manifest = {
        "batch": model.BATCH,
        "input_hw": model.INPUT_HW,
        "input_channels": 3,
        "num_classes": model.NUM_CLASSES,
        "seed": args.seed,
        "gemm_dim": GEMM_DIM,
        "models": {},
        "gemms": {},
        "conv_layers": [
            {"name": n, "kh": kh, "kw": kw, "cin": ci, "cout": co,
             "stride": s, "padding": p}
            for (n, kh, kw, ci, co, s, p) in model.CONV_LAYERS
        ],
    }

    variants = {"flex": list(model.DEFAULT_DATAFLOWS)}
    for df in DATAFLOWS:
        variants[df] = [df] * (len(model.CONV_LAYERS) + 1)

    for name, dfs in variants.items():
        path = f"model_{name}.hlo.txt"
        text = lower_model(params, dfs)
        with open(os.path.join(args.outdir, path), "w") as f:
            f.write(text)
        manifest["models"][name] = {"path": path, "dataflows": dfs}
        print(f"wrote {path}: {len(text)} chars")

    for df in DATAFLOWS:
        path = f"gemm_{df}.hlo.txt"
        text = lower_gemm(df)
        with open(os.path.join(args.outdir, path), "w") as f:
            f.write(text)
        manifest["gemms"][df] = {"path": path, "dim": GEMM_DIM}
        print(f"wrote {path}: {len(text)} chars")

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
