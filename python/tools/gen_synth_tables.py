#!/usr/bin/env python3
"""Offline replica of ``flex-tpu synth``: the per-layer dataflow-selection
tables WORKLOADS.md embeds.

Everything the Rust CLI prints for a sequence-family model is closed-form
— the seed-derived configs (``util::rng::Rng``), the GEMM lowering
(``topology/synth.rs``), the per-dataflow cycle counts
(``sim/dataflow/{is,os,ws}.rs``), the latency argmin with its IS > OS > WS
tie-break (``coordinator/plan.rs``) and the 45 nm energy model
(``cost/{pe,gates,energy}.rs``).  This module reimplements those formulas
from the spec, so ``synth_output(...)`` reproduces the CLI output without
running Rust, and the tables committed in WORKLOADS.md are verifiable
(``python/tests/test_workloads_doc.py`` checks them against a fresh run).

Deliberately dependency-free (stdlib only) so it runs on minimal CI
runners.
"""

import math

MASK64 = (1 << 64) - 1

# --- util::rng::Rng (splitmix64 scramble + xorshift64*) -------------------


class Rng:
    """Replica of ``rust/src/util/rng.rs``."""

    def __init__(self, seed):
        z = (seed + 0x9E3779B97F4A7C15) & MASK64
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        self.state = ((z ^ (z >> 31)) | 1) & MASK64

    def next_u64(self):
        x = self.state
        x ^= x >> 12
        x = (x ^ (x << 25)) & MASK64
        x ^= x >> 27
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & MASK64

    def range_u64(self, lo, hi):
        return lo + self.next_u64() % (hi - lo + 1)

    def pick(self, items):
        return items[self.range_u64(0, len(items) - 1)]


# --- topology/synth.rs: seed-derived configs and GEMM lowering ------------

LSTM_MAX_UNROLL = 32


def family_config(family, seed):
    """``SeqModel::from_seed`` — the draw order is part of the contract."""
    rng = Rng(seed)
    if family == "transformer":
        dh = rng.pick([32, 64])
        heads = rng.pick([4, 8, 12])
        return {
            "d_model": dh * heads,
            "heads": heads,
            "blocks": 2 + rng.range_u64(0, 2),
            "ffn_mult": 4,
        }
    if family == "lstm":
        return {
            "input": rng.pick([64, 128, 256]),
            "hidden": rng.pick([128, 256, 512]),
            "cells": 1 + rng.range_u64(0, 1),
            "classes": rng.pick([10, 100, 1000]),
        }
    if family == "mlp":
        return {
            "input": rng.pick([256, 784, 2048]),
            "width": rng.pick([512, 1024, 2048]),
            "hidden_layers": 2 + rng.range_u64(0, 2),
            "classes": rng.pick([10, 100, 1000]),
        }
    raise ValueError("unknown family %r" % family)


def layers(family, cfg, seq_len):
    """The per-layer GEMM list as ``(name, M, K, N)`` tuples."""
    s = max(seq_len, 1)
    out = []
    if family == "transformer":
        d, h = cfg["d_model"], cfg["heads"]
        dh = d // h
        f = d * cfg["ffn_mult"]
        for b in range(cfg["blocks"]):
            out += [
                ("blk%d_qkv" % b, s, d, 3 * d),
                ("blk%d_scores" % b, h * s, dh, s),
                ("blk%d_ctx" % b, h * s, s, dh),
                ("blk%d_proj" % b, s, d, d),
                ("blk%d_ffn_up" % b, s, d, f),
                ("blk%d_ffn_dn" % b, s, f, d),
            ]
    elif family == "lstm":
        hidden = cfg["hidden"]
        steps = min(s, LSTM_MAX_UNROLL)
        for c in range(cfg["cells"]):
            fed = cfg["input"] if c == 0 else hidden
            for i in range(steps):
                rows = s // steps + (1 if i < s % steps else 0)
                out.append(("cell%d_t%d" % (c, i), rows, fed + hidden, 4 * hidden))
        out.append(("head", 1, hidden, cfg["classes"]))
    elif family == "mlp":
        width = cfg["width"]
        out.append(("fc0", s, cfg["input"], width))
        for i in range(1, cfg["hidden_layers"] + 1):
            out.append(("fc%d" % i, s, width, width))
        out.append(("head", s, width, cfg["classes"]))
    else:
        raise ValueError("unknown family %r" % family)
    return out


# --- sim/dataflow: closed-form cycles and SRAM traffic per dataflow -------


def _ceil(a, b):
    return -(-a // b)


def dataflow_cost(df, m, k, n, r, c):
    """``(cycles, sram_accesses)`` of one GEMM under one dataflow."""
    skew = r + c - 2
    if df == "IS":
        folds = _ceil(m, r) * _ceil(k, c)
        accum = _ceil(m, r) * (_ceil(k, c) - 1)
        cycles = folds * (r + n + skew)
        traffic = folds * r * c + folds * n * c + folds * r * n + accum * r * n
    elif df == "OS":
        folds = _ceil(m, r) * _ceil(n, c)
        cycles = folds * (k + skew + r)
        traffic = folds * r * k + folds * c * k + folds * r * c
    elif df == "WS":
        folds = _ceil(k, r) * _ceil(n, c)
        accum = (_ceil(k, r) - 1) * _ceil(n, c)
        cycles = folds * (r + m + skew)
        traffic = folds * m * r + folds * r * c + folds * m * c + accum * m * c
    else:
        raise ValueError(df)
    return cycles, traffic


# --- cost/{gates,pe,energy}.rs: the 45 nm energy model --------------------

# Cell power in µW: (DFF, FULL_ADDER, AND2, MUX2), composed exactly as
# pe_cost() does so the f64 arithmetic matches bit for bit.
_DFF_UW, _FA_UW, _AND2_UW, _MUX2_UW = 0.35, 0.25, 0.05, 0.08
SRAM_PJ_PER_ACCESS = 1.2
LEAKAGE_FRACTION = 0.08
CLOCK_NS = 10.0


def flex_pe_power_uw():
    conv = 64 * _AND2_UW + 96 * _FA_UW + 48 * _DFF_UW  # 8x8 MAC + pipes
    delta = 8 * _DFF_UW + 40 * _MUX2_UW  # stationary reg + two muxes
    return conv + delta


def layer_energy_pj(macs, cycles, traffic, num_pes):
    """``layer_energy`` for the Flex PE, rounded to integer pJ like
    ``energy_cell_pj`` (half away from zero)."""
    power = flex_pe_power_uw()
    e_mac = power * CLOCK_NS * 1e-3
    leak_per_cycle = power * LEAKAGE_FRACTION * num_pes * CLOCK_NS * 1e-3
    total = macs * e_mac + traffic * SRAM_PJ_PER_ACCESS + cycles * leak_per_cycle
    return math.floor(total + 0.5)


# --- coordinator/plan.rs + metrics::Table: the synth CLI output -----------

DATAFLOWS = ["IS", "OS", "WS"]  # Dataflow::ALL — also the argmin tie-break


def render_table(header, rows):
    """Replica of ``metrics::Table::render`` with trailing blanks stripped
    (the CLI pads every cell, including the last column)."""
    widths = [
        max(len(header[i]), *(len(r[i]) for r in rows)) if rows else len(header[i])
        for i in range(len(header))
    ]
    line = lambda cells: "".join(
        c.ljust(widths[i]) + "  " for i, c in enumerate(cells)
    ).rstrip()
    out = [line(header), "-" * (sum(widths) + 2 * len(widths))]
    out += [line(r) for r in rows]
    return "\n".join(out)


def synth_output(family, seed, seq_len=128, size=32, objective="latency"):
    """The exact stdout of ``flex-tpu synth --family F --seed S --seq-len L
    --size SZ`` (latency objective), with per-line trailing blanks
    stripped."""
    assert objective == "latency", "only the latency argmin is replicated"
    cfg = family_config(family, seed)
    gemms = layers(family, cfg, seq_len)
    r = c = size
    rows, picks, cycle_grid = [], [], []
    for name, m, k, n in gemms:
        per_df = [dataflow_cost(df, m, k, n, r, c) for df in DATAFLOWS]
        cycles = [cy for cy, _ in per_df]
        best = min(range(3), key=lambda i: (cycles[i], i))  # strict-< argmin
        picks.append(best)
        cycle_grid.append(per_df)
        rows.append(
            [name, "%dx%dx%d" % (m, k, n), str(m * k * n)]
            + [str(cy) for cy in cycles]
            + [DATAFLOWS[best]]
        )
    table = render_table(
        ["Layer", "GEMM MxKxN", "MACs", "IS", "OS", "WS", "Selected"], rows
    )
    # Totals: per-layer winners + 1 reconfig cycle per dataflow change
    # (ArchConfig::square default reconfig_cycles = 1, first layer free).
    flex = sum(cycle_grid[i][picks[i]][0] for i in range(len(gemms)))
    flex += sum(1 for i in range(1, len(picks)) if picks[i] != picks[i - 1])
    energy = sum(
        layer_energy_pj(m * k * n, *cycle_grid[i][picks[i]], r * c)
        for i, (_, m, k, n) in enumerate(gemms)
    )
    out = [table, ""]
    out.append(
        "%s%d (%s, seq %d, %d layers) on %dx%d, objective %s"
        % (family, seed, family, seq_len, len(gemms), r, c, objective)
    )
    out.append("flex total: %d cycles" % flex)
    for i, df in enumerate(DATAFLOWS):
        static = sum(g[i][0] for g in cycle_grid)
        out.append(
            "  vs static %s: %d cycles, speedup %.3fx" % (df, static, static / flex)
        )
    out.append("flex energy: %.3f mJ" % (energy * 1e-9))
    return "\n".join(out)


SHOWCASE = [("transformer", 0), ("lstm", 0), ("mlp", 0)]


def main():
    for family, seed in SHOWCASE:
        print("$ flex-tpu synth --family %s --seed %d --seq-len 128" % (family, seed))
        print(synth_output(family, seed))
        print()


if __name__ == "__main__":
    main()
