#!/usr/bin/env python3
"""Offline replica of the Rust bench trace generator, used to cross-validate
the committed sequence-trace baseline.

The Rust side (``rust/src/bench/trace.rs``) generates serving-bench traces
from one explicit 64-bit LCG with pure integer arithmetic, so a
``(scenario, seed)`` pair names exactly one trace on every platform.  This
module reimplements that generator from the spec — the LCG recurrence, the
Q12 exponential-quantile gap table, the per-scenario draw order, the
sequence-length draw (strictly after the gap/model draws, only for
sequence models) and the power-of-two bucket rounding — without reading
any Rust output.  Running it writes
``rust/tests/golden/bench_seq_trace_baseline.json``; the Rust test
``seq_trace_matches_committed_python_replica_baseline`` replays the same
trace natively and must agree bit for bit, and
``python/tests/test_bench_baseline.py`` checks the committed file matches
a fresh replica run.

Deliberately dependency-free (stdlib only) so it runs on minimal CI
runners.
"""

import json
import os

MASK64 = (1 << 64) - 1

# -ln((i+0.5)/16) in Q12 fixed point — the 16 exponential quantile
# midpoints the gap sampler draws from.
EXP_Q12 = [
    14196, 9696, 7603, 6225, 5196, 4374, 3690, 3103,
    2591, 2135, 1725, 1353, 1011, 696, 403, 130,
]

# The gated mixed CNN+transformer scenario (must mirror seq_config() in
# rust/tests/bench.rs).
GATED = {
    "scenario": "mixed",
    "seed": 3,
    "requests": 400,
    "models": ["alexnet", "transformer3"],
    "mean_interarrival_us": 2000,
    "seq_min": 32,
    "seq_max": 128,
    "seq_models": [1],
}


class Lcg:
    """Knuth/Numerical-Recipes 64-bit LCG, high 32 bits per draw."""

    def __init__(self, seed):
        self.state = seed & MASK64
        self.next_u32()  # scramble step so nearby seeds diverge

    def next_u32(self):
        self.state = (self.state * 6364136223846793005 + 1442695040888963407) & MASK64
        return self.state >> 32

    def pick(self, n):
        return self.next_u32() % n


def exp_gap_us(lcg, mean_us):
    return mean_us * EXP_Q12[lcg.pick(16)] // 4096


def events(scenario, seed, requests, models, mean_us, seq=None):
    """Yield ``(at_us, id, model, seq_len)`` tuples; ``seq`` is a dict with
    ``min``/``max``/``models`` (indices that draw a sequence length)."""
    lcg = Lcg(seed)
    at = 0
    burst_left = 0
    burst_model = 0
    for eid in range(requests):
        if scenario == "mixed":
            at += exp_gap_us(lcg, mean_us)
            model = lcg.pick(models)
        elif scenario == "skewed":
            at += exp_gap_us(lcg, mean_us)
            r = lcg.pick((1 << models) - 1)
            model = 0
            weight = 1 << (models - 1)
            acc = weight
            while r >= acc:
                model += 1
                weight >>= 1
                acc += weight
        elif scenario == "bursty":
            if burst_left == 0:
                burst_left = 4 + lcg.pick(13)
                burst_model = lcg.pick(models)
                at += exp_gap_us(lcg, mean_us * 3)
            burst_left -= 1
            at += exp_gap_us(lcg, mean_us // 4 + 1)
            model = burst_model
        else:
            raise ValueError("unknown scenario %r" % scenario)
        seq_len = None
        if seq is not None and model in seq["models"]:
            if seq["min"] == seq["max"]:
                seq_len = seq["min"]
            else:
                span = seq["max"] - seq["min"] + 1
                seq_len = seq["min"] + lcg.pick(span)
        yield at, eid, model, seq_len


def bucket_of(seq_len, lo, hi):
    """Power-of-two bucket rounding: next_power_of_two(max(s, 1)) clamped
    to [lo, hi]."""
    s = max(seq_len, 1)
    b = 1 << (s - 1).bit_length()
    return min(max(b, lo), hi)


def fnv1a(h, data):
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & MASK64
    return h


def le8(x):
    return int(x).to_bytes(8, "little")


def baseline_doc(cfg=GATED):
    """The trace-baseline document for one gated config: aggregates plus an
    FNV-1a digest over the full event stream."""
    seq = {"min": cfg["seq_min"], "max": cfg["seq_max"], "models": cfg["seq_models"]}
    digest = 0xCBF29CE484222325
    last_at = 0
    seq_sum = 0
    count = 0
    offered = {}
    for at, eid, model, seq_len in events(
        cfg["scenario"],
        cfg["seed"],
        cfg["requests"],
        len(cfg["models"]),
        cfg["mean_interarrival_us"],
        seq,
    ):
        raw = 0 if seq_len is None else seq_len
        digest = fnv1a(digest, le8(at) + le8(eid) + le8(model) + le8(raw) + b";")
        last_at = at
        seq_sum += raw
        count += 1
        if seq_len is None:
            name = cfg["models"][model]
        else:
            b = bucket_of(seq_len, cfg["seq_min"], cfg["seq_max"])
            name = "%s@%d" % (cfg["models"][model], b)
        offered[name] = offered.get(name, 0) + 1
    return {
        "schema": 1,
        "scenario": cfg["scenario"],
        "seed": cfg["seed"],
        "requests": cfg["requests"],
        "models": cfg["models"],
        "mean_interarrival_us": cfg["mean_interarrival_us"],
        "seq_min": cfg["seq_min"],
        "seq_max": cfg["seq_max"],
        "seq_models": cfg["seq_models"],
        "events": count,
        "last_at_us": last_at,
        "seq_len_sum": seq_sum,
        "trace_digest": "%016x" % digest,
        "offered": dict(sorted(offered.items())),
    }


def main():
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
    path = os.path.normpath(
        os.path.join(root, "rust", "tests", "golden", "bench_seq_trace_baseline.json")
    )
    doc = baseline_doc()
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print("wrote %s (digest %s)" % (path, doc["trace_digest"]))


if __name__ == "__main__":
    main()
