//! Datacenter scalability study (paper Fig. 7 / §III-C): sweep array sizes
//! from edge (32x32) to TPU-v1 scale (256x256) and show the Flex-vs-OS gap
//! widening, with per-model detail and utilization.
//!
//! Run: `cargo run --release --example datacenter_scale`

use flex_tpu::config::ArchConfig;
use flex_tpu::coordinator::FlexPipeline;
use flex_tpu::metrics::{mean, sci, Table};
use flex_tpu::sim::Dataflow;
use flex_tpu::topology::zoo;

fn main() {
    let sizes = [32u32, 64, 128, 256];
    let mut summary = Table::new(&["S", "avg speedup vs OS", "avg speedup vs IS", "avg speedup vs WS"]);

    for s in sizes {
        let arch = ArchConfig::square(s);
        let pipeline = FlexPipeline::new(arch);
        let mut t = Table::new(&[
            "Model",
            "IS",
            "OS",
            "WS",
            "Flex",
            "Speedup vs OS",
            "Flex util",
        ]);
        let mut sp_os = Vec::new();
        let mut sp_is = Vec::new();
        let mut sp_ws = Vec::new();
        for topo in zoo::all_models() {
            let d = pipeline.deploy(&topo);
            sp_os.push(d.speedup_vs(Dataflow::Os));
            sp_is.push(d.speedup_vs(Dataflow::Is));
            sp_ws.push(d.speedup_vs(Dataflow::Ws));
            t.row(vec![
                topo.name.clone(),
                sci(d.static_cycles(Dataflow::Is)),
                sci(d.static_cycles(Dataflow::Os)),
                sci(d.static_cycles(Dataflow::Ws)),
                sci(d.total_cycles()),
                format!("{:.3}x", d.speedup_vs(Dataflow::Os)),
                format!("{:.3}", d.flex.utilization(&arch)),
            ]);
        }
        println!("== S = {s}x{s} ==\n{}", t.render());
        summary.row(vec![
            format!("{s}x{s}"),
            format!("{:.3}", mean(&sp_os)),
            format!("{:.3}", mean(&sp_is)),
            format!("{:.3}", mean(&sp_ws)),
        ]);
    }

    println!("== Scalability summary (paper Fig. 7: OS column grows) ==");
    println!("{}", summary.render());
}
