//! Edge deployment study: MobileNet + YOLO-Tiny on Coral-class arrays
//! (8x8 / 16x16), the paper's edge motivation — plus the cost model's
//! energy estimate per inference (extension, clearly beyond the paper).
//!
//! Run: `cargo run --release --example edge_deployment`

use flex_tpu::config::ArchConfig;
use flex_tpu::coordinator::FlexPipeline;
use flex_tpu::cost::energy;
use flex_tpu::cost::synth::critical_path_ns;
use flex_tpu::cost::PeVariant;
use flex_tpu::metrics::Table;
use flex_tpu::sim::Dataflow;
use flex_tpu::topology::zoo;

fn main() {
    let models = [zoo::mobilenet(), zoo::yolo_tiny()];
    let mut t = Table::new(&[
        "Array",
        "Model",
        "Flex cycles",
        "Best static",
        "Speedup",
        "Latency (ms)",
        "Energy/inf (mJ)",
    ]);

    for s in [8u32, 16] {
        let arch = ArchConfig::square(s);
        let pipeline = FlexPipeline::new(arch);
        let cpd_ns = critical_path_ns(s, PeVariant::Flex);
        for model in &models {
            let d = pipeline.deploy(model);
            let (best_df, best_cycles) = d.best_static();
            let latency_ms = d.total_cycles() as f64 * cpd_ns * 1e-6;
            // Full energy model: MAC + SRAM traffic + leakage (cost::energy).
            let energy_mj = energy::network_energy(&arch, PeVariant::Flex, &d.flex).total_mj();
            t.row(vec![
                format!("{s}x{s}"),
                model.name.clone(),
                d.total_cycles().to_string(),
                format!("{best_cycles} ({best_df})"),
                format!("{:.3}x", best_cycles as f64 / d.total_cycles() as f64),
                format!("{latency_ms:.2}"),
                format!("{energy_mj:.3}"),
            ]);
        }
    }
    println!("{}", t.render());

    // Edge arrays reconfigure more per cycle saved — show the CMU tables.
    for model in &models {
        let d = FlexPipeline::new(ArchConfig::square(8)).deploy(model);
        let table: Vec<String> = d
            .selection
            .per_layer
            .iter()
            .map(|df| df.name().to_string())
            .collect();
        println!("{} CMU table (8x8): {}", model.name, table.join(","));
        println!(
            "  transitions: {} (reconfig overhead {} cycles total)",
            d.flex.reconfig_cycles / d.arch.reconfig_cycles.max(1),
            d.flex.reconfig_cycles
        );
        for df in Dataflow::ALL {
            println!("  speedup vs {df}: {:.3}x", d.speedup_vs(df));
        }
    }
}
