//! Quickstart: deploy ResNet-18 on a 32x32 Flex-TPU and print the per-layer
//! dataflow selection plus the Table-I-style speedup summary.
//!
//! Run: `cargo run --release --example quickstart`

use flex_tpu::config::ArchConfig;
use flex_tpu::coordinator::FlexPipeline;
use flex_tpu::metrics::Table;
use flex_tpu::sim::Dataflow;
use flex_tpu::topology::zoo;

fn main() {
    // 1. Pick a workload from the zoo (or parse your own ScaleSim CSV with
    //    flex_tpu::topology::parse_csv).
    let model = zoo::resnet18();

    // 2. Describe the hardware: a 32x32 systolic array, paper defaults.
    let arch = ArchConfig::square(32);

    // 3. Run the paper's pre-deployment flow: profile each layer under
    //    IS/OS/WS, program the CMU with the per-layer argmin, simulate.
    let deployment = FlexPipeline::new(arch).deploy(&model);

    // 4. Inspect the per-layer selection (paper Fig. 1 content).
    let mut t = Table::new(&["Layer", "IS cycles", "OS cycles", "WS cycles", "CMU pick"]);
    for (i, layer) in model.layers.iter().enumerate() {
        let c = deployment.selection.cycles[i];
        t.row(vec![
            layer.name.clone(),
            c[0].to_string(),
            c[1].to_string(),
            c[2].to_string(),
            deployment.selection.per_layer[i].to_string(),
        ]);
    }
    println!("{}", t.render());

    // 5. The Table-I row for this model.
    println!("Flex-TPU total: {} cycles", deployment.total_cycles());
    for df in Dataflow::ALL {
        println!(
            "  static {df}: {:>9} cycles -> Flex speedup {:.3}x",
            deployment.static_cycles(df),
            deployment.speedup_vs(df)
        );
    }
    let wins = deployment.selection.wins();
    println!("layer wins IS/OS/WS: {}/{}/{}", wins[0], wins[1], wins[2]);
}
