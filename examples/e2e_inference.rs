//! End-to-end driver (DESIGN.md E8): the full three-layer stack on a real
//! workload.
//!
//! * L1/L2 (build time): `make artifacts` lowered the FlexNet-Tiny CNN —
//!   whose conv/FC GEMMs run through the Pallas systolic kernels — to HLO
//!   text with per-layer dataflows baked in.
//! * L3 (this binary): loads the artifacts via PJRT, deploys the network on
//!   a simulated 8x8 Flex-TPU (CMU profiling + programming), then serves
//!   batched inference requests: PJRT computes the logits, the simulator
//!   supplies the per-inference latency, and the report compares Flex
//!   against the three static-dataflow baselines.
//!
//! Python is not on the request path — only the compiled HLO is.
//!
//! Run: `make artifacts && cargo run --release --example e2e_inference`

use std::sync::mpsc;
use std::thread;

use flex_tpu::config::ArchConfig;
use flex_tpu::inference::{InferenceRequest, InferenceServer};
use flex_tpu::metrics::Table;
use flex_tpu::runtime::Runtime;
use flex_tpu::sim::Dataflow;

const REQUESTS: u64 = 128;
const ARRAY: u32 = 8; // Coral-Edge-class array for a tiny CNN

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let rt = Runtime::load(&dir)?;
    println!(
        "loaded {} model variants + {} gemm artifacts on {} (batch={})",
        rt.model_variants().len(),
        rt.manifest().gemms.len(),
        rt.platform(),
        rt.manifest().batch
    );
    let manifest = rt.manifest().clone();
    let server = InferenceServer::builder(ArchConfig::square(ARRAY))
        .runtime(rt)
        .build()?;

    // The deployment the CMU programmed for this network.
    let d = server.deployment();
    let mut t = Table::new(&["Layer", "IS", "OS", "WS", "CMU pick"]);
    let topo = manifest.topology();
    for (i, l) in topo.layers.iter().enumerate() {
        let c = d.selection.cycles[i];
        t.row(vec![
            l.name.clone(),
            c[0].to_string(),
            c[1].to_string(),
            c[2].to_string(),
            d.selection.per_layer[i].to_string(),
        ]);
    }
    println!("\n== FlexNet-Tiny on {ARRAY}x{ARRAY} Flex-TPU ==\n{}", t.render());
    for df in Dataflow::ALL {
        println!(
            "  static {df}: {} cycles (Flex speedup {:.3}x)",
            d.static_cycles(df),
            d.speedup_vs(df)
        );
    }

    // Serve a stream of synthetic images through the batched server.
    let (tx, rx) = mpsc::channel();
    let img = (manifest.input_hw * manifest.input_hw * manifest.input_channels) as usize;
    let producer = thread::spawn(move || {
        let mut pending = Vec::new();
        for id in 0..REQUESTS {
            let (otx, orx) = mpsc::channel();
            // Deterministic synthetic "image" per request id.
            let pixels: Vec<f32> = (0..img)
                .map(|p| (((id as usize * 31 + p * 7) % 97) as f32 / 97.0) - 0.5)
                .collect();
            let req = InferenceRequest {
                id,
                model: "flexnet_tiny".to_string(),
                pixels,
                deadline_us: None,
            };
            tx.send((req, otx)).unwrap();
            pending.push(orx);
        }
        drop(tx); // close the front door -> server drains and reports
        let mut histogram = vec![0u64; 10];
        for orx in pending {
            let resp: flex_tpu::inference::InferenceResponse =
                orx.recv().expect("response");
            histogram[resp.class % 10] += 1;
        }
        histogram
    });

    let stats = server.serve(rx)?;
    let histogram = producer.join().expect("producer");

    println!("\n== Serving run ==");
    println!("requests: {} in {} batches", stats.requests, stats.batches);
    println!("predicted-class histogram: {histogram:?}");
    println!(
        "host (PJRT CPU, functional): {:.1} req/s, mean {:.0} us/req",
        stats.host_throughput_rps, stats.mean_host_latency_us
    );
    println!(
        "simulated Flex-TPU: {:.2} us/inference ({} cycles @ flex critical path), {:.0} inf/s",
        stats.sim_flex_latency_ns / 1000.0,
        server.timing().flex_cycles,
        stats.sim_flex_throughput_ips
    );
    println!(
        "simulated speedup vs best static dataflow: {:.3}x",
        stats.sim_speedup_vs_best_static
    );
    println!("\nrecorded in EXPERIMENTS.md §E8");
    Ok(())
}
